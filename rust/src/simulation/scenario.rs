//! Scenario engine — named, seed-deterministic schedules for the
//! heterogeneous-edge simulator (`--scenario`, ROADMAP "handles as many
//! scenarios as you can imagine").
//!
//! A [`Scenario`] drives three axes of churn on top of the paper's static
//! fluctuation model:
//!
//! * **bandwidth drift** — a trace-driven [`NetworkTrace`] of per-round
//!   band multipliers (diurnal tides, flash-crowd congestion) applied to
//!   the WAN model's sampled links;
//! * **availability windows** — per-client on/off windows on the round
//!   axis of the virtual clock (a flash crowd joins for a window and
//!   leaves again);
//! * **mid-round dropouts** — a dispatched client vanishes at a fraction
//!   of its projected completion time: its broadcast is already billed,
//!   its upload never arrives, its update never merges
//!   (`coordinator::round`, "Scenario churn").
//!
//! # Catalog
//!
//! | name                  | bandwidth         | availability      | dropouts            |
//! |-----------------------|-------------------|-------------------|---------------------|
//! | `stable`              | paper model       | always on         | none                |
//! | `diurnal-bandwidth`   | 24-round tide     | always on         | none                |
//! | `flash-crowd-churn`   | congested in-window | crowd third windowed | 2% / 8% in-window |
//! | `correlated-dropout`  | paper model       | always on         | 2% + 50% bursts     |
//!
//! # JSON / CLI format
//!
//! CLI: `--scenario <name>`; config JSON: `"scenario": "<name>"` (same
//! catalog names), plus `--dropout-policy survivors|error` /
//! `"dropout_policy": "..."` for the full-barrier path's reaction to a
//! mid-round dropout (`config::DropoutPolicy`). Unknown names are parse
//! errors, never a silent fall-back to `stable`.
//!
//! # Determinism contract
//!
//! Every schedule quantity — the trace multiplier of a round, a client's
//! availability, whether/when a dispatched task drops — is a **pure
//! function of `(scenario, cfg.seed, round, client)`**: each draw uses a
//! fresh `Rng` keyed by those values (see `event_rng`), so evaluation
//! order, worker count, pool size and wall-clock never reach a decision.
//! Same seed ⇒ identical schedule for any `--workers`/`--pool` (pinned in
//! `tests/prop_coordinator.rs` and `tests/integration_parallel.rs`);
//! `stable` schedules nothing and is byte-identical to the historical
//! default path.

use crate::simulation::network::NetworkTrace;
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

/// The shippable catalog names, in `--scenario` order.
pub const SCENARIO_CATALOG: [&str; 4] =
    ["stable", "diurnal-bandwidth", "flash-crowd-churn", "correlated-dropout"];

const TRACE_SALT: u64 = 0x9E6B_5533_D00D_0001;
const AVAIL_SALT: u64 = 0x9E6B_5533_D00D_0002;
const DROP_SALT: u64 = 0x9E6B_5533_D00D_0003;

/// A fresh, independent RNG for one schedule event — the purity that
/// makes schedules identical for any evaluation order (module docs,
/// "Determinism contract"). Mixing mirrors `FlEnv::batch_stream`.
fn event_rng(seed: u64, salt: u64, round: usize, client: usize) -> Rng {
    let mix = salt
        .wrapping_add((round as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add((client as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03));
    Rng::new(seed ^ mix)
}

/// Typed churn faults surfaced by the round pipeline. `anyhow`-wrapped at
/// the driver boundary; downcast with `err.downcast_ref::<ScenarioError>()`.
/// (Not `Copy`: `MidRoundDropout` carries the full dropped-client list.)
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum ScenarioError {
    /// one or more participants vanished mid-round and the config said
    /// that is fatal (`--dropout-policy error`). Carries *every* dropped
    /// client of the round (assignment order), not just the first — an
    /// operator diagnosing a correlated burst needs the whole set.
    #[error(
        "round {round}: {} client(s) dropped mid-round (dropout policy: error): {dropped:?}",
        .dropped.len()
    )]
    MidRoundDropout { round: usize, dropped: Vec<usize> },
    /// every participant of the round dropped — no survivors to aggregate
    #[error("round {round}: every participant dropped mid-round — no survivors to aggregate")]
    EmptySurvivors { round: usize },
    /// churn left fewer survivors than the static `--quorum K` demands
    #[error(
        "round {round}: quorum K={required} infeasible — only {survivors} of the cohort \
         survived the churn"
    )]
    QuorumInfeasible { round: usize, required: usize, survivors: usize },
    /// a task whose fate was `Dropped`/`Faulted` was consumed as a merge
    /// input — quorum members and due late arrivals are chosen among
    /// survivors, so this is a scheduler bug, never a user error
    #[error(
        "round {round} task {index} (client {client}) was consumed as a merge input but \
         was {fate} — scheduler bug"
    )]
    PhantomMerge { round: usize, index: usize, client: usize, fate: &'static str },
}

/// A named churn schedule (module docs). Variants carry their canonical
/// catalog parameters; [`Scenario::Pinned`] is the surgical test hook
/// (drop exactly one `(round, client)`) and is not in the CLI catalog.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scenario {
    /// the historical default: no churn, byte-identical to pre-scenario runs
    Stable,
    /// bandwidth tide: band multiplier `1-depth ≤ m(r) ≤ 1` over a
    /// `period`-round cycle, with seeded per-round jitter (the "trace")
    DiurnalBandwidth { period: usize, depth: f64 },
    /// a crowd third of the fleet attends only a per-client-jittered
    /// window each period; during the *nominal* flash window
    /// `[flash_start, flash_start+flash_len)` the system is overloaded —
    /// the WAN congests and the **whole fleet** (crowd and steady alike)
    /// drops at `flash_drop` instead of `base_drop`
    FlashCrowdChurn {
        period: usize,
        flash_start: usize,
        flash_len: usize,
        /// clients with `client % crowd_stride == 0` are the crowd
        crowd_stride: usize,
        base_drop: f64,
        flash_drop: f64,
    },
    /// background dropout rate plus correlated bursts (network
    /// partitions) every `burst_every` rounds
    CorrelatedDropout { base: f64, burst_every: usize, burst_rate: f64 },
    /// test hook: client `client` drops at `frac` of its completion in
    /// round `round`, nothing else ever happens
    Pinned { round: usize, client: usize, frac: f64 },
}

impl Scenario {
    /// Parse a catalog name (CLI `--scenario`, JSON `"scenario"`).
    pub fn parse(s: &str) -> Result<Scenario> {
        match s {
            "stable" => Ok(Scenario::Stable),
            "diurnal-bandwidth" => Ok(Scenario::DiurnalBandwidth { period: 24, depth: 0.6 }),
            "flash-crowd-churn" => Ok(Scenario::FlashCrowdChurn {
                period: 24,
                flash_start: 8,
                flash_len: 8,
                crowd_stride: 3,
                base_drop: 0.02,
                flash_drop: 0.08,
            }),
            "correlated-dropout" => {
                Ok(Scenario::CorrelatedDropout { base: 0.02, burst_every: 8, burst_rate: 0.5 })
            }
            other => Err(anyhow!(
                "unknown scenario `{other}` (one of {})",
                SCENARIO_CATALOG.join("|")
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Stable => "stable",
            Scenario::DiurnalBandwidth { .. } => "diurnal-bandwidth",
            Scenario::FlashCrowdChurn { .. } => "flash-crowd-churn",
            Scenario::CorrelatedDropout { .. } => "correlated-dropout",
            Scenario::Pinned { .. } => "pinned",
        }
    }

    /// The schedule's cycle length in rounds (1 for aperiodic scenarios);
    /// every schedule quantity repeats with this period.
    pub fn period_rounds(&self) -> usize {
        match *self {
            Scenario::Stable | Scenario::Pinned { .. } => 1,
            Scenario::DiurnalBandwidth { period, .. } => period.max(1),
            Scenario::FlashCrowdChurn { period, .. } => period.max(1),
            Scenario::CorrelatedDropout { burst_every, .. } => burst_every.max(1),
        }
    }

    /// The per-round WAN band multiplier trace, if this scenario drifts
    /// bandwidth. Seed-deterministic; every multiplier lands in
    /// `[MIN_BANDWIDTH_SCALE, 1]` by construction.
    pub fn bandwidth_trace(&self, seed: u64) -> Option<NetworkTrace> {
        match *self {
            Scenario::DiurnalBandwidth { period, depth } => {
                let period = period.max(1);
                let mut rng = event_rng(seed, TRACE_SALT, 0, 0);
                let scales = (0..period)
                    .map(|r| {
                        let phase = std::f64::consts::TAU * r as f64 / period as f64;
                        let base = 1.0 - depth * 0.5 * (1.0 - phase.cos());
                        base * rng.uniform_in(0.9, 1.0)
                    })
                    .collect();
                Some(NetworkTrace::new(scales))
            }
            Scenario::FlashCrowdChurn { period, flash_start, flash_len, .. } => {
                // the crowd congests the WAN while its window is open
                let period = period.max(1);
                let scales = (0..period)
                    .map(|r| if in_window(r, flash_start, flash_len, period) { 0.6 } else { 1.0 })
                    .collect();
                Some(NetworkTrace::new(scales))
            }
            _ => None,
        }
    }

    /// Is `client` attending round `round`? Windows are single cyclic
    /// intervals on the round axis — at most two availability transitions
    /// per period, crossed in virtual-clock order (rounds are monotone on
    /// the clock). Pinned per `(seed, client)` phase jitter staggers the
    /// crowd's joins/leaves.
    pub fn available(&self, seed: u64, client: usize, round: usize) -> bool {
        match *self {
            Scenario::Stable
            | Scenario::DiurnalBandwidth { .. }
            | Scenario::CorrelatedDropout { .. }
            | Scenario::Pinned { .. } => true,
            Scenario::FlashCrowdChurn { period, flash_start, flash_len, crowd_stride, .. } => {
                if crowd_stride == 0 || client % crowd_stride != 0 {
                    return true; // the steady cohort never leaves
                }
                let period = period.max(1);
                let jitter =
                    event_rng(seed, AVAIL_SALT, 0, client).below(flash_len.max(2) / 2 + 1);
                in_window(round % period, (flash_start + jitter) % period, flash_len, period)
            }
        }
    }

    /// Does `client` vanish mid-round in `round`, and if so at what
    /// fraction of its projected completion time? One fresh RNG per
    /// `(seed, round, client)` — pure, order-independent.
    pub fn dropout(&self, seed: u64, round: usize, client: usize) -> Option<f64> {
        let rate = match *self {
            Scenario::Stable | Scenario::DiurnalBandwidth { .. } => return None,
            Scenario::Pinned { round: r, client: c, frac } => {
                return (round == r && client == c).then_some(frac);
            }
            Scenario::FlashCrowdChurn {
                period, flash_start, flash_len, base_drop, flash_drop, ..
            } => {
                if in_window(round % period.max(1), flash_start, flash_len, period.max(1)) {
                    flash_drop
                } else {
                    base_drop
                }
            }
            Scenario::CorrelatedDropout { base, burst_every, burst_rate } => {
                if burst_every > 0 && round % burst_every == burst_every - 1 {
                    burst_rate
                } else {
                    base
                }
            }
        };
        let mut rng = event_rng(seed, DROP_SALT, round, client);
        (rng.uniform() < rate).then(|| rng.uniform_in(0.05, 0.95))
    }
}

/// Membership of `r` in the cyclic window `[start, start+len)` mod `period`.
fn in_window(r: usize, start: usize, len: usize, period: usize) -> bool {
    if len == 0 {
        return false;
    }
    if len >= period {
        return true;
    }
    let end = start + len;
    if end <= period {
        (start..end).contains(&r)
    } else {
        r >= start || r < end - period
    }
}

/// Per-run scenario state held by `FlEnv`: the spec, the prebuilt
/// bandwidth trace, the plan/dispatch round cursors (every mode — serial,
/// overlapped, quorum — plans and dispatches rounds in the same order, so
/// the cursors are mode-independent) and the observed churn totals that
/// feed the adaptive quorum controller's dropout-rate signal.
#[derive(Debug, Clone)]
pub struct ScenarioCtl {
    spec: Scenario,
    seed: u64,
    trace: Option<NetworkTrace>,
    /// the round currently being planned (phase A)
    plan_round: usize,
    planned_rounds: usize,
    dispatched_rounds: usize,
    dispatched_tasks: usize,
    dropped_tasks: usize,
}

impl ScenarioCtl {
    pub fn new(spec: Scenario, seed: u64) -> ScenarioCtl {
        ScenarioCtl {
            trace: spec.bandwidth_trace(seed),
            spec,
            seed,
            plan_round: 0,
            planned_rounds: 0,
            dispatched_rounds: 0,
            dispatched_tasks: 0,
            dropped_tasks: 0,
        }
    }

    pub fn spec(&self) -> &Scenario {
        &self.spec
    }

    /// Advance the plan cursor (called once per round by
    /// `FlEnv::sample_clients`); subsequent `available_now`/
    /// `bandwidth_scale` reads refer to this round.
    pub fn begin_plan_round(&mut self) -> usize {
        let r = self.planned_rounds;
        self.planned_rounds += 1;
        self.plan_round = r;
        r
    }

    /// Advance the dispatch cursor (called once per dispatched round by
    /// `FlEnv::stamp_dropouts`).
    pub fn begin_dispatch_round(&mut self) -> usize {
        let r = self.dispatched_rounds;
        self.dispatched_rounds += 1;
        r
    }

    /// The WAN band multiplier of the round being planned; `None` means
    /// the scenario does not drift bandwidth (take the historical path).
    pub fn bandwidth_scale(&self) -> Option<f64> {
        self.trace.as_ref().map(|t| t.scale(self.plan_round))
    }

    /// Availability of `client` in the round being planned.
    pub fn available_now(&self, client: usize) -> bool {
        self.spec.available(self.seed, client, self.plan_round)
    }

    /// The dropout draw for a dispatched task.
    pub fn dropout(&self, round: usize, client: usize) -> Option<f64> {
        self.spec.dropout(self.seed, round, client)
    }

    /// Book one dispatched round's churn into the observed totals.
    pub fn note_dispatched(&mut self, tasks: usize, dropped: usize) {
        self.dispatched_tasks += tasks;
        self.dropped_tasks += dropped;
    }

    /// Observed mid-round dropout rate over everything dispatched so far
    /// (the adaptive quorum controller's churn signal). Deterministic
    /// virtual-schedule state — dropouts are decided at dispatch, never
    /// by worker racing.
    pub fn observed_dropout_rate(&self) -> f64 {
        if self.dispatched_tasks == 0 {
            0.0
        } else {
            self.dropped_tasks as f64 / self.dispatched_tasks as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulation::network::MIN_BANDWIDTH_SCALE;

    #[test]
    fn catalog_parses_and_names_round_trip() {
        for name in SCENARIO_CATALOG {
            let s = Scenario::parse(name).unwrap();
            assert_eq!(s.name(), name, "catalog name must round-trip");
        }
        assert!(Scenario::parse("chaos-monkey").is_err());
        assert_eq!(Scenario::parse("stable").unwrap(), Scenario::Stable);
    }

    #[test]
    fn stable_schedules_nothing() {
        let s = Scenario::Stable;
        assert!(s.bandwidth_trace(42).is_none());
        for round in 0..50 {
            for client in 0..20 {
                assert!(s.available(42, client, round));
                assert_eq!(s.dropout(42, round, client), None);
            }
        }
    }

    #[test]
    fn diurnal_trace_is_bounded_and_periodic() {
        let s = Scenario::parse("diurnal-bandwidth").unwrap();
        let t = s.bandwidth_trace(7).unwrap();
        let period = s.period_rounds();
        for r in 0..3 * period {
            let m = t.scale(r);
            assert!((MIN_BANDWIDTH_SCALE..=1.0).contains(&m), "scale {m} out of band");
            assert_eq!(m, t.scale(r + period), "trace must be {period}-round periodic");
        }
        // the tide actually moves
        let (lo, hi) = t.bounds();
        assert!(hi - lo > 0.2, "diurnal depth collapsed: [{lo}, {hi}]");
    }

    #[test]
    fn flash_crowd_windows_are_single_cyclic_intervals() {
        let s = Scenario::parse("flash-crowd-churn").unwrap();
        let period = s.period_rounds();
        for client in 0..24 {
            let avail: Vec<bool> = (0..period).map(|r| s.available(5, client, r)).collect();
            let transitions = (0..period)
                .filter(|&r| avail[r] != avail[(r + 1) % period])
                .count();
            assert!(
                transitions <= 2,
                "client {client}: {transitions} availability transitions in one period"
            );
            // periodic on the round axis (monotone on the virtual clock)
            for r in 0..period {
                assert_eq!(s.available(5, client, r), s.available(5, client, r + period));
            }
        }
        // the steady two thirds never leave
        assert!((0..3 * period).all(|r| s.available(5, 1, r)));
        // the crowd third does leave at some point
        let Scenario::FlashCrowdChurn { crowd_stride, .. } = s else { unreachable!() };
        assert!((0..period).any(|r| !s.available(5, crowd_stride, r)));
    }

    #[test]
    fn pinned_dropout_hits_exactly_its_target() {
        let s = Scenario::Pinned { round: 3, client: 7, frac: 0.5 };
        assert_eq!(s.dropout(1, 3, 7), Some(0.5));
        assert_eq!(s.dropout(1, 3, 6), None);
        assert_eq!(s.dropout(1, 2, 7), None);
        assert!(s.available(1, 7, 3), "pinned dropout must not touch availability");
    }

    #[test]
    fn correlated_bursts_drop_harder() {
        let s = Scenario::parse("correlated-dropout").unwrap();
        let Scenario::CorrelatedDropout { burst_every, .. } = s else { unreachable!() };
        let burst_round = burst_every - 1;
        let rate = |round: usize| {
            (0..2000).filter(|&c| s.dropout(11, round, c).is_some()).count() as f64 / 2000.0
        };
        assert!(rate(burst_round) > 0.4, "burst round must drop ~50%");
        assert!(rate(0) < 0.06, "calm round must drop ~2%");
    }

    #[test]
    fn schedules_are_pure_and_order_independent() {
        // the worker-count-independence core: recomputing any schedule
        // entry, in any order, yields identical values
        for name in SCENARIO_CATALOG {
            let s = Scenario::parse(name).unwrap();
            let fwd: Vec<_> = (0..40)
                .flat_map(|r| (0..10).map(move |c| (r, c)))
                .map(|(r, c)| (s.available(9, c, r), s.dropout(9, r, c)))
                .collect();
            let rev: Vec<_> = (0..40)
                .flat_map(|r| (0..10).map(move |c| (r, c)))
                .rev()
                .map(|(r, c)| (s.available(9, c, r), s.dropout(9, r, c)))
                .rev()
                .collect();
            assert_eq!(fwd, rev, "{name}: schedule must not depend on evaluation order");
        }
    }

    #[test]
    fn ctl_tracks_cursors_and_dropout_rate() {
        let mut ctl = ScenarioCtl::new(Scenario::Stable, 1);
        assert_eq!(ctl.begin_plan_round(), 0);
        assert_eq!(ctl.begin_plan_round(), 1);
        assert_eq!(ctl.begin_dispatch_round(), 0);
        assert_eq!(ctl.observed_dropout_rate(), 0.0, "no dispatches yet");
        ctl.note_dispatched(8, 2);
        assert!((ctl.observed_dropout_rate() - 0.25).abs() < 1e-12);
        ctl.note_dispatched(8, 0);
        assert!((ctl.observed_dropout_rate() - 0.125).abs() < 1e-12);
    }

    #[test]
    fn in_window_handles_wrap_and_degenerate_lengths() {
        assert!(!in_window(3, 5, 0, 10), "empty window contains nothing");
        assert!(in_window(3, 5, 10, 10), "full-period window contains everything");
        // plain interval [2, 5)
        assert!(in_window(2, 2, 3, 10) && in_window(4, 2, 3, 10));
        assert!(!in_window(5, 2, 3, 10) && !in_window(1, 2, 3, 10));
        // wrapping interval [8, 8+4) mod 10 = {8, 9, 0, 1}
        for r in [8, 9, 0, 1] {
            assert!(in_window(r, 8, 4, 10), "round {r} must be inside the wrapped window");
        }
        for r in [2, 7] {
            assert!(!in_window(r, 8, 4, 10), "round {r} must be outside the wrapped window");
        }
    }
}
