//! Seeded engine-level fault schedules (`--faults`).
//!
//! Scenario dropouts ([`scenario`](super::scenario)) model *scheduled*
//! churn: a client cleanly vanishes on the virtual clock. Real edge
//! fleets fail in more ways — an execute errors mid-round, a payload
//! arrives corrupted, a link stalls without dying. This module draws
//! those **engine-level faults as seeded schedule facts**: every fault
//! is a pure function of `(faults cfg, seed, round, client)` through a
//! per-event keyed RNG (the `stamp_dropouts` discipline — one fresh RNG
//! per `(class, round, client)` event, no shared cursor), so fault runs
//! are byte-identical for any `--workers`/`--pool`/`--overlap` count and
//! `--faults off` draws nothing at all: it never even constructs an RNG.
//!
//! # Fault classes
//!
//! * [`FaultClass::Exec`] — the client's PJRT execute errors on round h.
//!   `severity` consecutive attempts fail before one would succeed; the
//!   retry policy decides whether the coordinator pays for them.
//! * [`FaultClass::Corrupt`] — the client's encoded `HWU1` upload frame
//!   arrives bit-flipped. In wire mode the round driver actually flips
//!   the drawn bit ([`crate::codec::corrupt_frame`]) and observes the
//!   codec's typed `CodecError` before recovering; in analytic mode
//!   (nothing is serialized) only the retry time cost applies.
//! * [`FaultClass::Partition`] — a transient network partition: the
//!   link *delays* delivery by a drawn `stall` rather than dropping.
//!
//! At most one fault is drawn per `(round, client)` task, with the fixed
//! precedence exec > corrupt > partition (each class still burns only
//! its own keyed RNG, so schedules stay pure under any evaluation
//! order). What happens to a drawn fault — retry with virtual-clock
//! backoff, re-plan the survivor set, or fail the run typed — is the
//! `--fault-policy` layer's job (`coordinator::resilience`).

use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

// Per-class schedule salts, continuing the scenario engine's family
// (`TRACE`/`AVAIL`/`DROP` = …0001/…0002/…0003).
const EXEC_SALT: u64 = 0x9E6B_5533_D00D_0004;
const CORRUPT_SALT: u64 = 0x9E6B_5533_D00D_0005;
const PARTITION_SALT: u64 = 0x9E6B_5533_D00D_0006;

/// Retry attempts a drawn exec/corrupt fault can burn at most — the
/// geometric severity draw is capped here so `severity` stays small and
/// enumerable in tests.
pub const MAX_SEVERITY: u32 = 4;

/// One fresh RNG per schedule event, keyed on `(seed, salt, round,
/// client)` — the same mixing discipline as the scenario engine, so no
/// schedule quantity shares a cursor with any other.
fn event_rng(seed: u64, salt: u64, round: usize, client: usize) -> Rng {
    let mix = salt
        .wrapping_add((round as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add((client as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03));
    Rng::new(seed ^ mix)
}

/// Typed fault classes (see the module docs for semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultClass {
    /// PJRT execute error on round h
    Exec,
    /// bit-flipped `HWU1` upload frame (typed `CodecError` on decode)
    Corrupt,
    /// transient partition: delivery delayed by a stall, not dropped
    Partition,
}

/// Every class, in schedule-precedence order.
pub const FAULT_CLASSES: [FaultClass; 3] =
    [FaultClass::Exec, FaultClass::Corrupt, FaultClass::Partition];

impl FaultClass {
    pub fn name(self) -> &'static str {
        match self {
            FaultClass::Exec => "exec",
            FaultClass::Corrupt => "corrupt",
            FaultClass::Partition => "partition",
        }
    }

    fn salt(self) -> u64 {
        match self {
            FaultClass::Exec => EXEC_SALT,
            FaultClass::Corrupt => CORRUPT_SALT,
            FaultClass::Partition => PARTITION_SALT,
        }
    }
}

/// One drawn fault event — a schedule fact, not an outcome. The policy
/// layer (`coordinator::resilience`) turns it into a retry delay, a
/// lost task or a typed abort.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub class: FaultClass,
    /// consecutive failing attempts before one would succeed
    /// (exec/corrupt; always 1 for partition), in `1..=MAX_SEVERITY`
    pub severity: u32,
    /// fraction of the task's unfaulted completion spent before the
    /// fault manifests, in `[0.05, 0.95)`
    pub frac: f64,
    /// partition stall (virtual seconds; 0 for other classes)
    pub stall: f64,
    /// corrupt-payload bit draw — the injection site flips bit
    /// `bit % 40` of the frame (the magic+version prefix, so decode
    /// always surfaces a typed error); 0 for other classes
    pub bit: u64,
}

/// The `--faults` knob: per-class injection rates. All-zero (the
/// parse of `off`, and the default) schedules nothing and consumes no
/// RNG — byte-identical to the pre-fault repo.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultsCfg {
    pub exec: f64,
    pub corrupt: f64,
    pub partition: f64,
}

impl FaultsCfg {
    /// Parse `off` | comma-separated `<class>=<rate>` items, e.g.
    /// `exec=0.1,corrupt=0.05,partition=0.2` (order-free, each class at
    /// most once, rates in (0, 1]). Unknown classes, bad rates and
    /// repeats are typed errors, never a silent fall-back.
    pub fn parse(s: &str) -> Result<FaultsCfg> {
        if s == "off" {
            return Ok(FaultsCfg::default());
        }
        let mut cfg = FaultsCfg::default();
        if s.is_empty() {
            return Err(anyhow!("empty --faults (expect off | exec=R,corrupt=R,partition=R)"));
        }
        for item in s.split(',') {
            let Some((class, rate)) = item.split_once('=') else {
                return Err(anyhow!(
                    "bad --faults item `{item}` in `{s}` (expect <class>=<rate>)"
                ));
            };
            let rate: f64 = rate
                .parse()
                .map_err(|_| anyhow!("bad fault rate `{rate}` in `{s}`"))?;
            if !(rate > 0.0 && rate <= 1.0) {
                return Err(anyhow!("fault rate must be in (0, 1], got {rate} in `{s}`"));
            }
            let slot = match class {
                "exec" => &mut cfg.exec,
                "corrupt" => &mut cfg.corrupt,
                "partition" => &mut cfg.partition,
                other => {
                    return Err(anyhow!(
                        "unknown fault class `{other}` in `{s}` (exec|corrupt|partition)"
                    ))
                }
            };
            if *slot != 0.0 {
                return Err(anyhow!("fault class `{class}` repeated in `{s}`"));
            }
            *slot = rate;
        }
        Ok(cfg)
    }

    /// Canonical knob string (inverse of [`FaultsCfg::parse`]).
    pub fn name(&self) -> String {
        if self.is_off() {
            return "off".into();
        }
        FAULT_CLASSES
            .iter()
            .filter(|c| self.rate(**c) > 0.0)
            .map(|c| format!("{}={}", c.name(), self.rate(*c)))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// True when no class can fire — the byte-identical default.
    pub fn is_off(&self) -> bool {
        self.exec == 0.0 && self.corrupt == 0.0 && self.partition == 0.0
    }

    pub fn rate(&self, class: FaultClass) -> f64 {
        match class {
            FaultClass::Exec => self.exec,
            FaultClass::Corrupt => self.corrupt,
            FaultClass::Partition => self.partition,
        }
    }

    /// Draw the fault (if any) for one `(round, client)` task — a pure,
    /// stateless function of `(self, seed, round, client)`. Classes roll
    /// independently on their own keyed RNGs and the first firing class
    /// in precedence order wins, so at most one fault rides a task and
    /// shuffled evaluation can never change a draw. When `self.is_off()`
    /// no RNG is ever constructed.
    pub fn draw(&self, seed: u64, round: usize, client: usize) -> Option<FaultEvent> {
        for class in FAULT_CLASSES {
            let rate = self.rate(class);
            if rate <= 0.0 {
                continue;
            }
            let mut rng = event_rng(seed, class.salt(), round, client);
            if rng.uniform() >= rate {
                continue;
            }
            let frac = rng.uniform_in(0.05, 0.95);
            let mut ev = FaultEvent { class, severity: 1, frac, stall: 0.0, bit: 0 };
            match class {
                FaultClass::Exec | FaultClass::Corrupt => {
                    // geometric severity, capped: most faults clear on
                    // the first retry, a tail needs several
                    while ev.severity < MAX_SEVERITY && rng.uniform() < 0.4 {
                        ev.severity += 1;
                    }
                    if class == FaultClass::Corrupt {
                        ev.bit = rng.next_u64();
                    }
                }
                FaultClass::Partition => ev.stall = rng.uniform_in(2.0, 30.0),
            }
            return Some(ev);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knob_parses_the_documented_grammar() {
        assert_eq!(FaultsCfg::parse("off").unwrap(), FaultsCfg::default());
        let c = FaultsCfg::parse("exec=0.1,corrupt=0.05,partition=0.2").unwrap();
        assert_eq!(c, FaultsCfg { exec: 0.1, corrupt: 0.05, partition: 0.2 });
        let c = FaultsCfg::parse("partition=1").unwrap();
        assert_eq!(c, FaultsCfg { exec: 0.0, corrupt: 0.0, partition: 1.0 });
        for bad in [
            "",
            "on",
            "exec",
            "exec=",
            "exec=0",
            "exec=1.5",
            "exec=x",
            "flake=0.1",
            "exec=0.1,exec=0.2",
        ] {
            assert!(FaultsCfg::parse(bad).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn knob_name_is_parse_inverse() {
        for s in ["off", "exec=0.1", "corrupt=0.05", "exec=0.1,partition=0.2"] {
            let c = FaultsCfg::parse(s).unwrap();
            assert_eq!(c.name(), s);
            assert_eq!(FaultsCfg::parse(&c.name()).unwrap(), c, "{s}");
        }
    }

    #[test]
    fn off_draws_nothing() {
        let off = FaultsCfg::default();
        assert!(off.is_off());
        for round in 0..20 {
            for client in 0..20 {
                assert_eq!(off.draw(42, round, client), None);
            }
        }
    }

    #[test]
    fn schedules_are_pure_and_order_independent() {
        // the determinism contract: a draw depends only on
        // (cfg, seed, round, client) — re-evaluating the grid in any
        // order reproduces it exactly
        let cfg = FaultsCfg::parse("exec=0.3,corrupt=0.25,partition=0.3").unwrap();
        let grid: Vec<((usize, usize), Option<FaultEvent>)> = (0..12)
            .flat_map(|r| (0..12).map(move |c| ((r, c), cfg.draw(7, r, c))))
            .collect();
        let mut shuffled: Vec<(usize, usize)> = grid.iter().map(|(k, _)| *k).collect();
        Rng::new(99).shuffle(&mut shuffled);
        for (r, c) in shuffled {
            let want = grid.iter().find(|(k, _)| *k == (r, c)).unwrap().1;
            assert_eq!(cfg.draw(7, r, c), want, "draw ({r}, {c}) not pure");
        }
    }

    #[test]
    fn draws_hit_their_class_rates_and_bounds() {
        let cfg = FaultsCfg::parse("exec=0.15,corrupt=0.1,partition=0.2").unwrap();
        let (mut n, mut fired) = (0usize, [0usize; 3]);
        for round in 0..60 {
            for client in 0..60 {
                n += 1;
                let Some(ev) = cfg.draw(1234, round, client) else { continue };
                fired[FAULT_CLASSES.iter().position(|c| *c == ev.class).unwrap()] += 1;
                assert!((1..=MAX_SEVERITY).contains(&ev.severity), "severity {}", ev.severity);
                assert!((0.05..0.95).contains(&ev.frac), "frac {}", ev.frac);
                match ev.class {
                    FaultClass::Partition => {
                        assert!((2.0..30.0).contains(&ev.stall), "stall {}", ev.stall);
                        assert_eq!(ev.severity, 1);
                    }
                    FaultClass::Exec => assert_eq!((ev.stall, ev.bit), (0.0, 0)),
                    FaultClass::Corrupt => assert_eq!(ev.stall, 0.0),
                }
            }
        }
        // exec rolls first so its observed rate is its nominal rate;
        // later classes are shadowed by precedence, so only a loose
        // lower bound applies
        let exec_rate = fired[0] as f64 / n as f64;
        assert!((exec_rate - 0.15).abs() < 0.03, "exec rate {exec_rate}");
        assert!(fired[1] > 0 && fired[2] > 0, "shadowed classes must still fire: {fired:?}");
    }

    #[test]
    fn precedence_allows_at_most_one_fault_per_task() {
        // rate-1 classes: exec always wins the precedence order
        let cfg = FaultsCfg::parse("exec=1,corrupt=1,partition=1").unwrap();
        for client in 0..20 {
            assert_eq!(cfg.draw(5, 0, client).unwrap().class, FaultClass::Exec);
        }
    }
}
