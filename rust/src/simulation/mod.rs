//! Heterogeneous edge-network simulator (paper §VI-C).
//!
//! The paper simulates 100 virtual clients on a workstation: per-client
//! iteration time follows a Gaussian whose mean/variance come from
//! physical device records (laptop, Jetson TX2, Xavier NX, AGX Xavier),
//! and WAN bandwidth fluctuates per round (1–5 Mb/s up, 10–20 Mb/s down).
//! We reproduce exactly that model: *learning* is real (PJRT executions),
//! *time* is virtual — completion/waiting/traffic metrics integrate the
//! simulated quantities (Eq. 17–20).
//!
//! # Scenarios (`--scenario`)
//!
//! On top of the static fluctuation model, the [`scenario`] engine layers
//! named, seed-deterministic churn schedules: trace-driven bandwidth
//! drift ([`NetworkTrace`] multipliers on the WAN band), per-client
//! availability windows on the virtual clock, and mid-round dropouts
//! (a dispatched client vanishes; its update never merges — see
//! `coordinator::round`, "Scenario churn"). The shipped catalog is
//! `stable` / `diurnal-bandwidth` / `flash-crowd-churn` /
//! `correlated-dropout` ([`SCENARIO_CATALOG`]).
//!
//! **JSON format.** Config files select a scenario with a catalog-name
//! string, and the full-barrier dropout reaction with a policy string:
//!
//! ```json
//! { "scenario": "flash-crowd-churn", "dropout_policy": "survivors" }
//! ```
//!
//! (CLI parity: `--scenario <name>`, `--dropout-policy survivors|error`.
//! Unknown names are parse errors, never a silent fall-back.)
//!
//! **Determinism contract.** Every schedule quantity is a pure function
//! of `(scenario, cfg.seed, round, client)` — one fresh RNG per event,
//! no worker/pool/wall-clock state — so churn runs are byte-identical
//! for any `--workers`/`--pool`, and `--scenario stable` schedules
//! nothing at all: it reproduces the historical default path byte for
//! byte (both pinned in `tests/integration_parallel.rs`; the schedule
//! purity itself in `tests/prop_coordinator.rs`).
//!
//! # Fault injection (`--faults`)
//!
//! The [`faults`] module layers *engine-level* failures on top of the
//! scenario engine's scheduled churn: typed fault classes — `exec`
//! (PJRT execute errors), `corrupt` (bit-flipped `HWU1` upload frames
//! surfacing as typed `CodecError`s) and `partition` (links that delay
//! delivery by a drawn stall rather than dropping) — drawn per
//! `(round, client)` behind per-class rates (`--faults
//! exec=R,corrupt=R,partition=R`; `off` is the default). **Faults are
//! seeded schedule facts**: every draw is a pure function of
//! `(cfg, seed, round, client)` through a per-event keyed RNG — never a
//! wall-clock race — so faulted runs are byte-identical for any
//! `--workers`/`--pool`/`--overlap` and `--faults off` consumes no RNG
//! at all (byte-identical to the pre-fault repo). What the coordinator
//! does about a drawn fault — retry, re-plan, or fail typed — is the
//! `--fault-policy` layer (`coordinator::resilience`).
//!
//! # Population model (`--population lazy`)
//!
//! The [`population`] module scales the same world to millions of
//! clients: instead of enumerating a fleet and a dataset per client,
//! a [`Population`] holds only the *priors* (capability-tier mix,
//! data-size prior + jitter, availability via the scenario engine) and
//! derives any client's device class, per-round throughput/link draws
//! and shard descriptor as pure functions of `(seed, client, round)` —
//! the same per-event-RNG idiom as the scenario schedules, so
//! materialization order and caching are unobservable. Cohorts are
//! sampled in O(K) by a sparse partial Fisher–Yates that replays
//! `Rng::sample_distinct`'s exact draw sequence, and per-client state is
//! memoized in a bounded, counting [`LazyCache`] whose stats let tests
//! pin the O(cohort) bound at 1e5+ populations. The eager path stays the
//! default and is byte-identical to its historical self; the sampling
//! contract for cohorts, links and shards is documented on the
//! [`population`] module itself.

// The determinism layers promise typed errors, never panics: promote
// slice-index panics to clippy warnings here (CI denies warnings);
// hlint rule P1 enforces the same contract with per-line reasons.
#![warn(clippy::indexing_slicing)]


pub mod clock;
pub mod device;
pub mod faults;
pub mod network;
pub mod population;
pub mod scenario;

pub use clock::{TrafficMeter, VirtualClock};
pub use device::{ClientDevice, DeviceClass, DeviceFleet};
pub use faults::{FaultClass, FaultEvent, FaultsCfg, FAULT_CLASSES, MAX_SEVERITY};
pub use network::{LinkSample, NetworkModel, NetworkTrace};
pub use population::{CacheStats, LazyCache, Population, PopulationSpec, ShardSpec};
pub use scenario::{Scenario, ScenarioCtl, ScenarioError, SCENARIO_CATALOG};
