//! Heterogeneous edge-network simulator (paper §VI-C).
//!
//! The paper simulates 100 virtual clients on a workstation: per-client
//! iteration time follows a Gaussian whose mean/variance come from
//! physical device records (laptop, Jetson TX2, Xavier NX, AGX Xavier),
//! and WAN bandwidth fluctuates per round (1–5 Mb/s up, 10–20 Mb/s down).
//! We reproduce exactly that model: *learning* is real (PJRT executions),
//! *time* is virtual — completion/waiting/traffic metrics integrate the
//! simulated quantities (Eq. 17–20).

pub mod clock;
pub mod device;
pub mod network;

pub use clock::{TrafficMeter, VirtualClock};
pub use device::{ClientDevice, DeviceClass, DeviceFleet};
pub use network::{LinkSample, NetworkModel};
