//! WAN bandwidth model.
//!
//! Paper §VI-C: download fluctuates in [10, 20] Mb/s, upload in
//! [1, 5] Mb/s, per client per round. Upload dominates completion time
//! (Eq. 18 only counts upload; downloads are an order of magnitude
//! faster) but both directions are metered for the traffic figures.
//!
//! Scenarios (`simulation::scenario`) drift the band per round through a
//! [`NetworkTrace`] of multipliers ([`NetworkModel::sample_scaled`]); the
//! trace floor is [`MIN_BANDWIDTH_SCALE`], but transfer times are guarded
//! anyway — a dead (0 Mb/s or non-finite) link saturates at
//! [`MAX_TRANSFER_SECS`] instead of leaking `inf`/NaN into the virtual
//! clock and the quorum ranking.

use crate::util::rng::Rng;

/// bytes per second per Mb/s
pub const MBIT: f64 = 1_000_000.0 / 8.0;

/// Hard floor for trace multipliers: a scenario may starve a link, never
/// kill it outright (a killed link is modeled as a dropout instead).
pub const MIN_BANDWIDTH_SCALE: f64 = 0.05;

/// Transfer-time saturation (~31 virtual years): the value a degenerate
/// link (0 Mb/s, NaN, negative) yields instead of a non-finite time. Far
/// beyond any experiment horizon, yet finite — Eq. 19 maxima and the
/// quorum completion ranking stay total.
pub const MAX_TRANSFER_SECS: f64 = 1e9;

/// Seconds to move `bytes` over a `bps` link, saturating on degenerate
/// bandwidth (see [`MAX_TRANSFER_SECS`]).
fn transfer_time(bytes: u64, bps: f64) -> f64 {
    // NaN is caught by the finiteness check, so `<= 0.0` is total here
    if !bps.is_finite() || bps <= 0.0 {
        return MAX_TRANSFER_SECS;
    }
    (crate::util::cast::bytes_to_f64(bytes) / bps).min(MAX_TRANSFER_SECS)
}

/// One round's sampled link for a client.
#[derive(Debug, Clone, Copy)]
pub struct LinkSample {
    /// bytes/second
    pub up_bps: f64,
    /// bytes/second
    pub down_bps: f64,
}

impl LinkSample {
    /// Seconds to upload `bytes` (paper Eq. 18). Saturating: a 0 Mb/s or
    /// non-finite link (trace-driven links can legitimately hit the
    /// floor) yields [`MAX_TRANSFER_SECS`], never `inf`/NaN.
    pub fn upload_time(&self, bytes: u64) -> f64 {
        transfer_time(bytes, self.up_bps)
    }

    /// Seconds to download `bytes`. Saturating like [`LinkSample::upload_time`].
    pub fn download_time(&self, bytes: u64) -> f64 {
        transfer_time(bytes, self.down_bps)
    }
}

/// Fluctuating-uniform WAN model.
#[derive(Debug, Clone)]
pub struct NetworkModel {
    pub up_lo_mbps: f64,
    pub up_hi_mbps: f64,
    pub down_lo_mbps: f64,
    pub down_hi_mbps: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel { up_lo_mbps: 1.0, up_hi_mbps: 5.0, down_lo_mbps: 10.0, down_hi_mbps: 20.0 }
    }
}

impl NetworkModel {
    // hlint::allow(unkeyed_rng): the eager fleet path threads per-client forked cursors here; the lazy path passes a per-event keyed link RNG — byte-compat pinned by goldens
    pub fn sample(&self, rng: &mut Rng) -> LinkSample {
        LinkSample {
            up_bps: rng.uniform_in(self.up_lo_mbps, self.up_hi_mbps) * MBIT,
            down_bps: rng.uniform_in(self.down_lo_mbps, self.down_hi_mbps) * MBIT,
        }
    }

    /// [`NetworkModel::sample`] under a trace multiplier: both directions
    /// scaled by `scale`. Consumes the RNG identically to the unscaled
    /// path (the determinism contract cares about draw counts).
    // hlint::allow(unkeyed_rng): same cursor-threading contract as `sample` — the caller owns keying; draw-count lockstep is the pinned invariant
    pub fn sample_scaled(&self, rng: &mut Rng, scale: f64) -> LinkSample {
        let base = self.sample(rng);
        LinkSample { up_bps: base.up_bps * scale, down_bps: base.down_bps * scale }
    }
}

/// A cyclic per-round band-multiplier trace (scenario-generated).
/// Construction clamps every entry into `[MIN_BANDWIDTH_SCALE, 1]` and
/// replaces non-finite entries with 1.0, so a trace can starve a link but
/// never hand the clock a degenerate value.
#[derive(Debug, Clone)]
pub struct NetworkTrace {
    scales: Vec<f64>,
}

impl NetworkTrace {
    pub fn new(scales: Vec<f64>) -> NetworkTrace {
        let mut scales: Vec<f64> = scales
            .into_iter()
            .map(|s| if s.is_finite() { s.clamp(MIN_BANDWIDTH_SCALE, 1.0) } else { 1.0 })
            .collect();
        if scales.is_empty() {
            scales.push(1.0);
        }
        NetworkTrace { scales }
    }

    /// The multiplier of `round` (cyclic).
    #[allow(clippy::indexing_slicing)]
    pub fn scale(&self, round: usize) -> f64 {
        // hlint::allow(panic_path): index is `% len` and construction guarantees a non-empty trace
        self.scales[round % self.scales.len()]
    }

    pub fn len(&self) -> usize {
        self.scales.len()
    }

    pub fn is_empty(&self) -> bool {
        false // construction guarantees at least one entry
    }

    /// (min, max) multiplier over the cycle.
    pub fn bounds(&self) -> (f64, f64) {
        let lo = self.scales.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = self.scales.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        (lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_within_paper_ranges() {
        let m = NetworkModel::default();
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let l = m.sample(&mut rng);
            assert!((1.0 * MBIT..5.0 * MBIT).contains(&l.up_bps));
            assert!((10.0 * MBIT..20.0 * MBIT).contains(&l.down_bps));
            assert!(l.down_bps > l.up_bps, "download must be faster than upload");
        }
    }

    #[test]
    fn transfer_times() {
        let l = LinkSample { up_bps: 2.0 * MBIT, down_bps: 10.0 * MBIT };
        // 1 MB at 2 Mb/s = 4 s
        assert!((l.upload_time(1_000_000) - 4.0).abs() < 1e-9);
        assert!((l.download_time(1_000_000) - 0.8).abs() < 1e-9);
    }

    #[test]
    fn degenerate_links_saturate_instead_of_inf() {
        // regression (scenario engine): a trace-driven link at 0 Mb/s used
        // to put `inf` into the projected completion, which the dispatch
        // validation then rejected — saturate to a finite horizon instead
        for bps in [0.0, -1.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let l = LinkSample { up_bps: bps, down_bps: bps };
            assert_eq!(l.upload_time(1_000_000), MAX_TRANSFER_SECS, "up_bps {bps}");
            assert_eq!(l.download_time(1_000_000), MAX_TRANSFER_SECS, "down_bps {bps}");
        }
        // 0 bytes over a dead link is still the saturation, not 0/0 = NaN
        let dead = LinkSample { up_bps: 0.0, down_bps: 0.0 };
        assert_eq!(dead.upload_time(0), MAX_TRANSFER_SECS);
        // a near-dead link whose quotient overflows f64 saturates too
        let tiny = LinkSample { up_bps: f64::MIN_POSITIVE, down_bps: f64::MIN_POSITIVE };
        assert_eq!(tiny.upload_time(u64::MAX), MAX_TRANSFER_SECS);
        // healthy links are untouched (bit-exact: min() with a larger cap)
        let l = LinkSample { up_bps: 2.0 * MBIT, down_bps: 10.0 * MBIT };
        assert_eq!(l.upload_time(1_000_000).to_bits(), (1_000_000.0 / (2.0 * MBIT)).to_bits());
    }

    #[test]
    fn scaled_samples_shrink_both_directions() {
        let m = NetworkModel::default();
        let mut a = Rng::new(3);
        let mut b = Rng::new(3);
        for _ in 0..200 {
            let base = m.sample(&mut a);
            let half = m.sample_scaled(&mut b, 0.5);
            assert_eq!(half.up_bps.to_bits(), (base.up_bps * 0.5).to_bits());
            assert_eq!(half.down_bps.to_bits(), (base.down_bps * 0.5).to_bits());
        }
        // identical RNG consumption: the two streams stay in lockstep
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn trace_clamps_and_cycles() {
        let t = NetworkTrace::new(vec![0.0, 2.0, f64::NAN, 0.5]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.scale(0), MIN_BANDWIDTH_SCALE, "0 clamps to the floor");
        assert_eq!(t.scale(1), 1.0, "overshoot clamps to 1");
        assert_eq!(t.scale(2), 1.0, "NaN is replaced, not propagated");
        assert_eq!(t.scale(3), 0.5);
        assert_eq!(t.scale(7), 0.5, "trace is cyclic");
        let (lo, hi) = t.bounds();
        assert!((MIN_BANDWIDTH_SCALE..=1.0).contains(&lo) && hi <= 1.0);
        // empty traces degrade to the identity multiplier
        let empty = NetworkTrace::new(Vec::new());
        assert_eq!(empty.scale(0), 1.0);
        assert!(!empty.is_empty());
    }
}
