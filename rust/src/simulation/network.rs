//! WAN bandwidth model.
//!
//! Paper §VI-C: download fluctuates in [10, 20] Mb/s, upload in
//! [1, 5] Mb/s, per client per round. Upload dominates completion time
//! (Eq. 18 only counts upload; downloads are an order of magnitude
//! faster) but both directions are metered for the traffic figures.

use crate::util::rng::Rng;

const MBIT: f64 = 1_000_000.0 / 8.0; // bytes per second per Mb/s

/// One round's sampled link for a client.
#[derive(Debug, Clone, Copy)]
pub struct LinkSample {
    /// bytes/second
    pub up_bps: f64,
    /// bytes/second
    pub down_bps: f64,
}

impl LinkSample {
    /// Seconds to upload `bytes` (paper Eq. 18).
    pub fn upload_time(&self, bytes: usize) -> f64 {
        bytes as f64 / self.up_bps
    }

    /// Seconds to download `bytes`.
    pub fn download_time(&self, bytes: usize) -> f64 {
        bytes as f64 / self.down_bps
    }
}

/// Fluctuating-uniform WAN model.
#[derive(Debug, Clone)]
pub struct NetworkModel {
    pub up_lo_mbps: f64,
    pub up_hi_mbps: f64,
    pub down_lo_mbps: f64,
    pub down_hi_mbps: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel { up_lo_mbps: 1.0, up_hi_mbps: 5.0, down_lo_mbps: 10.0, down_hi_mbps: 20.0 }
    }
}

impl NetworkModel {
    pub fn sample(&self, rng: &mut Rng) -> LinkSample {
        LinkSample {
            up_bps: rng.uniform_in(self.up_lo_mbps, self.up_hi_mbps) * MBIT,
            down_bps: rng.uniform_in(self.down_lo_mbps, self.down_hi_mbps) * MBIT,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_within_paper_ranges() {
        let m = NetworkModel::default();
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let l = m.sample(&mut rng);
            assert!((1.0 * MBIT..5.0 * MBIT).contains(&l.up_bps));
            assert!((10.0 * MBIT..20.0 * MBIT).contains(&l.down_bps));
            assert!(l.down_bps > l.up_bps, "download must be faster than upload");
        }
    }

    #[test]
    fn transfer_times() {
        let l = LinkSample { up_bps: 2.0 * MBIT, down_bps: 10.0 * MBIT };
        // 1 MB at 2 Mb/s = 4 s
        assert!((l.upload_time(1_000_000) - 4.0).abs() < 1e-9);
        assert!((l.download_time(1_000_000) - 0.8).abs() < 1e-9);
    }
}
