//! Integration: manifest + PJRT engine over the real artifacts.
//!
//! Requires `make artifacts` (skipped gracefully when absent so plain
//! `cargo test` in a fresh checkout still passes the rest of the suite).

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]


use heroes::data::loader::{Batch, ImageLoader, TextLoader};
use heroes::data::synth_image::ImageGen;
use heroes::data::synth_text::TextGen;
use heroes::model::{full_selections, ComposedGlobal, DenseGlobal};
use heroes::runtime::{Engine, ExecKind, Manifest, Value};
use heroes::tensor::Tensor;
use heroes::util::rng::Rng;
use std::sync::Arc;

fn engine_or_skip() -> Option<Engine> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(Manifest::load(&dir).unwrap()).unwrap())
}

#[test]
fn manifest_lists_all_families_and_execs() {
    let Some(engine) = engine_or_skip() else { return };
    let m = engine.manifest();
    for fam in ["cnn", "resnet", "rnn"] {
        let info = m.model(fam).unwrap();
        assert_eq!(info.cap_p, 4);
        for p in 1..=4 {
            assert!(m.exec(&Manifest::train_name(fam, p, true)).is_ok());
            assert!(m.exec(&Manifest::train_name(fam, p, false)).is_ok());
            assert!(m.exec(&Manifest::probe_name(fam, p)).is_ok());
            assert!(info.flops_composed[&p] > 0.0);
            assert!(info.bytes_composed[&p] > 0);
            // factorized transfer must be smaller than dense at larger widths
            if p == 4 {
                assert!(
                    info.bytes_composed[&p] < info.bytes_dense[&p],
                    "{fam}: composed {} !< dense {}",
                    info.bytes_composed[&p],
                    info.bytes_dense[&p]
                );
            }
        }
        assert_eq!(m.exec(&Manifest::eval_name(fam, true)).unwrap().kind, ExecKind::Eval);
        assert_eq!(m.exec(&Manifest::eval_name(fam, false)).unwrap().kind, ExecKind::Eval);
    }
}

#[test]
fn composed_cnn_train_step_runs_and_learns() {
    let Some(engine) = engine_or_skip() else { return };
    let info = engine.manifest().model("cnn").unwrap().clone();
    let mut rng = Rng::new(42);
    let global = ComposedGlobal::init(&info, &mut rng).unwrap();

    let ds = Arc::new(ImageGen::cifar_twin().generate(64, 7, &mut rng));
    let mut loader = ImageLoader::new(ds, (0..64).collect(), info.batch, Rng::new(1));
    let Batch { x, y } = loader.next_batch();
    let lr = Tensor::from_vec(&[1], vec![0.05]);

    let p = 2;
    let sels: Vec<Vec<usize>> = info.layers.iter().map(|l| (0..l.blocks_at(p)).collect()).collect();
    let mut params = global.reduced_inputs(&info, p, &sels).unwrap();
    let name = Manifest::train_name("cnn", p, true);

    let mut losses = Vec::new();
    for _ in 0..8 {
        let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
        inputs.push(Value::F32(&x));
        inputs.push(Value::I32(&y));
        inputs.push(Value::F32(&lr));
        let out = engine.execute(&name, &inputs).unwrap();
        assert_eq!(out.len(), params.len() + 2);
        let loss = out[params.len()].data()[0];
        let gsq = out[params.len() + 1].data()[0];
        assert!(loss.is_finite() && gsq >= 0.0);
        losses.push(loss);
        params = out[..2 * info.layers.len() + 1].to_vec();
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
    let st = engine.stats();
    assert_eq!(st.compiles, 1, "executable must be cached");
    assert_eq!(st.executions, 8);
}

#[test]
fn composed_eval_reports_sane_accuracy() {
    let Some(engine) = engine_or_skip() else { return };
    let info = engine.manifest().model("cnn").unwrap().clone();
    let mut rng = Rng::new(11);
    let global = ComposedGlobal::init(&info, &mut rng).unwrap();
    let ds = ImageGen::cifar_twin().generate(info.eval_batch, 7, &mut rng);

    let params = global.full_inputs(&info);
    let mut x = vec![0.0f32; info.eval_batch * ds.sample_size()];
    let mut y = vec![0i32; info.eval_batch];
    for i in 0..info.eval_batch {
        x[i * ds.sample_size()..(i + 1) * ds.sample_size()].copy_from_slice(ds.sample(i));
        y[i] = ds.labels[i];
    }
    let xt = Tensor::from_vec(&[info.eval_batch, ds.hw, ds.hw, ds.channels], x);
    let yt = heroes::tensor::IntTensor::from_vec(&[info.eval_batch], y);

    let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
    inputs.push(Value::F32(&xt));
    inputs.push(Value::I32(&yt));
    let out = engine.execute(&Manifest::eval_name("cnn", true), &inputs).unwrap();
    let loss_sum = out[0].data()[0];
    let correct = out[1].data()[0];
    assert!(loss_sum > 0.0 && loss_sum.is_finite());
    assert!((0.0..=info.eval_batch as f32).contains(&correct));
}

#[test]
fn probe_gradient_has_manifest_dim_and_matches_structure() {
    let Some(engine) = engine_or_skip() else { return };
    let info = engine.manifest().model("cnn").unwrap().clone();
    let mut rng = Rng::new(13);
    let global = ComposedGlobal::init(&info, &mut rng).unwrap();
    let ds = Arc::new(ImageGen::cifar_twin().generate(32, 7, &mut rng));
    let mut loader = ImageLoader::new(ds, (0..32).collect(), info.batch, Rng::new(2));
    let Batch { x, y } = loader.next_batch();

    let p = 1;
    let sels: Vec<Vec<usize>> = info.layers.iter().map(|l| (0..l.blocks_at(p)).collect()).collect();
    let params = global.reduced_inputs(&info, p, &sels).unwrap();
    let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
    inputs.push(Value::F32(&x));
    inputs.push(Value::I32(&y));
    let out = engine.execute(&Manifest::probe_name("cnn", p), &inputs).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), info.probe_dim[&p]);
    assert!(out[0].sq_norm() > 0.0, "gradient must be non-zero");
}

#[test]
fn dense_train_step_runs_for_all_widths() {
    let Some(engine) = engine_or_skip() else { return };
    let info = engine.manifest().model("cnn").unwrap().clone();
    let mut rng = Rng::new(17);
    let global = DenseGlobal::init(&info, &mut rng).unwrap();
    let ds = Arc::new(ImageGen::cifar_twin().generate(32, 7, &mut rng));
    let mut loader = ImageLoader::new(ds, (0..32).collect(), info.batch, Rng::new(3));
    let Batch { x, y } = loader.next_batch();
    let lr = Tensor::from_vec(&[1], vec![0.05]);

    for p in 1..=info.cap_p {
        let params = global.reduced_inputs(&info, p).unwrap();
        let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
        inputs.push(Value::F32(&x));
        inputs.push(Value::I32(&y));
        inputs.push(Value::F32(&lr));
        let out = engine
            .execute(&Manifest::train_name("cnn", p, false), &inputs)
            .unwrap();
        let loss = out[params.len()].data()[0];
        assert!(loss.is_finite(), "p={p} loss {loss}");
    }
}

#[test]
fn rnn_train_step_runs() {
    let Some(engine) = engine_or_skip() else { return };
    let info = engine.manifest().model("rnn").unwrap().clone();
    let mut rng = Rng::new(19);
    let global = ComposedGlobal::init(&info, &mut rng).unwrap();
    let ts = TextGen::shakespeare_twin().generate(1, 2_000, 100, 5);
    let mut loader = TextLoader::new(Arc::new(ts.shards[0].clone()), info.batch, 20, Rng::new(4));
    let b = loader.next_batch();
    let lr = Tensor::from_vec(&[1], vec![0.1]);

    let sels = full_selections(&info);
    let params = global.reduced_inputs(&info, info.cap_p, &sels).unwrap();
    let mut inputs: Vec<Value> = params.iter().map(Value::F32).collect();
    inputs.push(Value::I32(&b.x));
    inputs.push(Value::I32(&b.y));
    inputs.push(Value::F32(&lr));
    let out = engine
        .execute(&Manifest::train_name("rnn", info.cap_p, true), &inputs)
        .unwrap();
    let loss = out[params.len()].data()[0];
    assert!(loss.is_finite() && loss > 0.0);
}

#[test]
fn engine_rejects_shape_mismatches() {
    let Some(engine) = engine_or_skip() else { return };
    let bad = Tensor::zeros(&[3, 3]);
    let inputs = vec![Value::F32(&bad)];
    assert!(engine.execute("cnn_train_p1", &inputs).is_err());
    assert!(engine.execute("no_such_exec", &[]).is_err());
}
