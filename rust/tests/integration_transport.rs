//! The networked transport's contracts (`transport` module docs;
//! requires the `net` cargo feature):
//!
//! 1. **Sim-parity** — the simulation is the oracle: a `--transport
//!    tcp` run over loopback executors records the exact series a
//!    `--transport sim` run records — same plans, chosen K, aggregated
//!    model (test loss/acc fingerprint) and billed bytes — through the
//!    serial, overlapped and quorum pipelines alike.
//! 2. **Liveness under executor loss** — a killed client, a silent
//!    coordinator with no executor, and a protocol-violating peer all
//!    complete their tasks (`Dropped` / `Faulted` with `0.0` virtual
//!    timestamps) instead of hanging the drive loop.
//! 3. **Stamped fates never ship** — dropout and unrecovered-fault
//!    stamps resolve at dispatch, before any socket is touched.
//!
//! The parity tests need `make artifacts` and skip gracefully
//! otherwise; the liveness tests hand-build tasks and run on any
//! machine.

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]

use heroes::config::{ExperimentConfig, QuorumKnob, Scale};
use heroes::coordinator::env::{BatchStream, FixedBatches};
use heroes::coordinator::resilience::{FaultAction, FaultStamp};
use heroes::coordinator::round::{LocalTask, TaskFate};
use heroes::coordinator::XData;
use heroes::experiments::{run_scheme, StopCondition};
use heroes::metrics::Recorder;
use heroes::runtime::{EnginePool, Manifest};
use heroes::simulation::{FaultClass, FaultEvent};
use heroes::tensor::{IntTensor, Tensor};
use heroes::transport::tcp::{TcpCfg, TcpTransport};
use heroes::transport::{proto, Transport, TransportCfg};
use std::time::Duration;

fn pool_or_skip(engines: usize) -> Option<EnginePool> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(EnginePool::new(Manifest::load(&dir).unwrap(), engines).unwrap())
}

/// A fully hand-built task — the liveness tests never execute it, so
/// the executable names are decorative; what matters is that every
/// synthesis fact (client, bytes) echoes back in the fate.
fn fake_task(client: usize) -> LocalTask {
    let x = XData::Image(Tensor::from_vec(&[1, 2], vec![0.25, -1.5]));
    let y = IntTensor::from_vec(&[1], vec![1]);
    LocalTask {
        client,
        p: 1,
        tau: 1,
        lr: 0.05,
        train_exec: "cnn_train_p1".into(),
        probe_exec: None,
        payload: vec![Tensor::from_vec(&[2], vec![1.0, 2.0])],
        stream: BatchStream::Fixed(FixedBatches::new(vec![(x, y)]).unwrap()),
        bytes: 4096,
        up_bytes: 4096,
        rebill_bytes: 0,
        wire: None,
        completion: 3.5,
        drop_at: None,
        fault: None,
    }
}

/// Short timeouts so liveness failures surface in milliseconds, not CI
/// minutes.
fn quick_cfg() -> TcpCfg {
    let mut cfg = TcpCfg::new("127.0.0.1:0");
    cfg.accept_timeout = Duration::from_millis(250);
    cfg.io_timeout = Duration::from_millis(1500);
    cfg
}

#[test]
fn stamped_fates_resolve_at_dispatch_without_a_socket() {
    // An hour-long accept timeout proves the point: if a stamped task
    // ever reached the network path, recv would hang far past the test
    // timeout. Both stamps must complete instantly, echoing the stamp's
    // own virtual facts (never the wall clock).
    let mut cfg = quick_cfg();
    cfg.accept_timeout = Duration::from_secs(3600);
    let mut tp = TcpTransport::bind(cfg).unwrap();

    let mut dropped = fake_task(3);
    dropped.drop_at = Some(2.5);
    let mut faulted = fake_task(5);
    faulted.fault = Some(FaultStamp {
        event: FaultEvent { class: FaultClass::Exec, severity: 1, frac: 0.5, stall: 0.0, bit: 0 },
        action: FaultAction::Retry,
        retries: 2,
        recovered: false,
        fault_time: 7.0,
    });
    tp.dispatch(0, vec![dropped, faulted]).unwrap();

    let c0 = tp.recv().unwrap();
    assert_eq!((c0.seq, c0.index), (0, 0));
    match c0.outcome.unwrap() {
        TaskFate::Dropped(d) => {
            assert_eq!((d.client, d.bytes), (3, 4096));
            assert_eq!(d.drop_time, 2.5, "the stamp's virtual drop time must survive");
        }
        other => panic!("expected Dropped, got {other:?}"),
    }
    let c1 = tp.recv().unwrap();
    assert_eq!((c1.seq, c1.index), (0, 1));
    match c1.outcome.unwrap() {
        TaskFate::Faulted(f) => {
            assert_eq!((f.client, f.bytes), (5, 4096));
            assert_eq!(f.class, FaultClass::Exec);
            assert_eq!(f.retries, 2);
            assert_eq!(f.fault_time, 7.0, "the stamp's virtual fault time must survive");
        }
        other => panic!("expected Faulted, got {other:?}"),
    }
    tp.close();
}

#[test]
fn no_executor_completes_the_task_as_dropped() {
    // Nobody ever connects: after accept_timeout the task must come
    // back Dropped with a 0.0 virtual timestamp — wall time decided
    // *whether* the fate arrived, never *what* it says.
    let mut tp = TcpTransport::bind(quick_cfg()).unwrap();
    tp.dispatch(7, vec![fake_task(2)]).unwrap();
    let c = tp.recv().unwrap();
    assert_eq!((c.seq, c.index), (7, 0));
    match c.outcome.unwrap() {
        TaskFate::Dropped(d) => {
            assert_eq!((d.client, d.bytes), (2, 4096));
            assert_eq!(d.drop_time, 0.0, "no wall-clock quantity may enter a virtual field");
        }
        other => panic!("expected Dropped, got {other:?}"),
    }
    tp.close();
}

#[test]
fn killed_client_completes_its_tasks_as_dropped() {
    // A client that greets, accepts the task, then vanishes: the server
    // must settle everything the connection owed as Dropped.
    let mut tp = TcpTransport::bind(quick_cfg()).unwrap();
    let addr = tp.addr();
    let killed = std::thread::spawn(move || {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        proto::write_msg(&mut s, proto::KIND_HELLO, &proto::hello_body()).unwrap();
        let (kind, body) = proto::read_msg(&mut s, proto::FRAME_CAP).unwrap().unwrap();
        assert_eq!(kind, proto::KIND_TASK);
        let (seq, index, task) = proto::decode_task_msg(&body).unwrap();
        assert_eq!((seq, index, task.client), (9, 0, 4));
        // dropping the stream here kills the connection mid-task
    });
    tp.dispatch(9, vec![fake_task(4)]).unwrap();
    let c = tp.recv().unwrap();
    assert_eq!((c.seq, c.index), (9, 0));
    match c.outcome.unwrap() {
        TaskFate::Dropped(d) => {
            assert_eq!((d.client, d.bytes), (4, 4096));
            assert_eq!(d.drop_time, 0.0);
        }
        other => panic!("expected Dropped, got {other:?}"),
    }
    killed.join().unwrap();
    tp.close();
}

#[test]
fn protocol_violation_completes_its_tasks_as_faulted() {
    // A peer that greets, accepts the task, then answers garbage: the
    // connection is poisoned and its owed tasks complete as Faulted.
    let mut tp = TcpTransport::bind(quick_cfg()).unwrap();
    let addr = tp.addr();
    let rogue = std::thread::spawn(move || {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        proto::write_msg(&mut s, proto::KIND_HELLO, &proto::hello_body()).unwrap();
        let (kind, _body) = proto::read_msg(&mut s, proto::FRAME_CAP).unwrap().unwrap();
        assert_eq!(kind, proto::KIND_TASK);
        proto::write_msg(&mut s, proto::KIND_RESULT, &[0xFF, 0xFF, 0xFF]).unwrap();
        // hold the socket open until the server hangs up, so the test
        // can't mistake a connection loss for the protocol verdict
        let _ = proto::read_msg(&mut s, proto::FRAME_CAP);
    });
    tp.dispatch(1, vec![fake_task(6)]).unwrap();
    let c = tp.recv().unwrap();
    assert_eq!((c.seq, c.index), (1, 0));
    match c.outcome.unwrap() {
        TaskFate::Faulted(f) => {
            assert_eq!((f.client, f.bytes), (6, 4096));
            assert_eq!(f.class, FaultClass::Corrupt);
            assert_eq!((f.retries, f.fault_time), (0, 0.0));
        }
        other => panic!("expected Faulted, got {other:?}"),
    }
    tp.close();
    rogue.join().unwrap();
}

// ---------------------------------------------------------------------
// Sim-parity: the simulation is the oracle (artifacts-gated)
// ---------------------------------------------------------------------

fn tiny_cfg(workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("cnn", Scale::Smoke);
    cfg.n_clients = 8;
    cfg.k_per_round = 4;
    cfg.samples_per_client = 32;
    cfg.test_samples = 128;
    cfg.tau_default = 3;
    cfg.tau_max = 12;
    cfg.workers = workers;
    cfg.rounds = 2;
    cfg.eval_every = 1;
    cfg
}

/// Field-by-field sample comparison (exact — parity is byte-exact, not
/// approximate; `Sample` deliberately has no `PartialEq`).
fn assert_series_identical(sim: &Recorder, tcp: &Recorder, what: &str) {
    assert_eq!(sim.samples.len(), tcp.samples.len(), "{what}: eval cadence diverged");
    for (i, (a, b)) in sim.samples.iter().zip(&tcp.samples).enumerate() {
        assert_eq!(a.round, b.round, "{what}: sample {i} round");
        assert_eq!(a.sim_time, b.sim_time, "{what}: sample {i} virtual clock");
        assert_eq!(a.traffic_gb, b.traffic_gb, "{what}: sample {i} billed traffic");
        assert_eq!(a.down_bytes, b.down_bytes, "{what}: sample {i} billed downlink bytes");
        assert_eq!(a.up_bytes, b.up_bytes, "{what}: sample {i} billed uplink bytes");
        assert_eq!(a.test_loss, b.test_loss, "{what}: sample {i} model fingerprint (loss)");
        assert_eq!(a.test_acc, b.test_acc, "{what}: sample {i} model fingerprint (acc)");
        assert_eq!(a.avg_wait, b.avg_wait, "{what}: sample {i} waiting time");
        assert_eq!(a.mean_train_loss, b.mean_train_loss, "{what}: sample {i} train loss");
        assert_eq!(a.block_variance, b.block_variance, "{what}: sample {i} block variance");
    }
}

/// One scheme through `run_scheme` under the given transport; the tcp
/// route spins up `workers` loopback executor threads inside
/// `run_scheme` itself (the `with_loopback` topology).
fn run_with(pool: &EnginePool, mut cfg: ExperimentConfig, scheme: &str, tcp: bool) -> Recorder {
    cfg.transport =
        if tcp { TransportCfg::Tcp("127.0.0.1:0".into()) } else { TransportCfg::Sim };
    run_scheme(pool, &cfg, scheme, StopCondition::default()).unwrap()
}

#[test]
fn tcp_loopback_reproduces_the_simulation_byte_for_byte() {
    // The acceptance pin: same seed, same cfg → a tcp run over loopback
    // executors records the sim run's series exactly, for the Heroes
    // scheme (probe rounds, composed payloads) and the dense baseline.
    let Some(pool) = pool_or_skip(2) else { return };
    for scheme in ["heroes", "fedavg"] {
        let sim = run_with(&pool, tiny_cfg(2), scheme, false);
        let net = run_with(&pool, tiny_cfg(2), scheme, true);
        assert_series_identical(&sim, &net, scheme);
    }
}

#[test]
fn tcp_parity_holds_on_the_overlapped_and_quorum_pipelines() {
    // The other two drive loops ride the same transport seam: the
    // overlapped chunk pipeline and the semi-async K-of-N quorum (whose
    // chosen K and staleness weights are plan facts, so they must
    // survive the network unchanged).
    let Some(pool) = pool_or_skip(2) else { return };
    let overlap = |mut cfg: ExperimentConfig| {
        cfg.overlap = true;
        cfg
    };
    let quorum = |mut cfg: ExperimentConfig| {
        cfg.quorum = QuorumKnob::Fixed(3);
        cfg.rounds = 3;
        cfg
    };
    let sim = run_with(&pool, overlap(tiny_cfg(2)), "heroes", false);
    let net = run_with(&pool, overlap(tiny_cfg(2)), "heroes", true);
    assert_series_identical(&sim, &net, "heroes/overlap");

    let sim = run_with(&pool, quorum(tiny_cfg(2)), "heroes", false);
    let net = run_with(&pool, quorum(tiny_cfg(2)), "heroes", true);
    assert_series_identical(&sim, &net, "heroes/quorum");
}

#[test]
fn tcp_run_is_reproducible_across_invocations() {
    // Socket scheduling, executor racing and round-robin routing must
    // leave no residue: two tcp runs with the same seed are identical.
    let Some(pool) = pool_or_skip(2) else { return };
    let a = run_with(&pool, tiny_cfg(2), "heroes", true);
    let b = run_with(&pool, tiny_cfg(2), "heroes", true);
    assert_series_identical(&a, &b, "heroes/tcp-repro");
}
