//! Golden-trace regression harness: 2-round Heroes/dense/Flanc runs
//! under pinned seeds fingerprint `(sim_time, traffic_gb, chosen K)` per
//! eval point, and the fingerprints are diffed against checked-in
//! `rust/tests/golden/*.json`.
//!
//! * floats are pinned as **exact bit patterns** (hex of `f64::to_bits`)
//!   plus a human-readable value — any numerical drift in the round
//!   pipeline, the scenario engine or the schemes fails the diff;
//! * `HEROES_REGEN_GOLDEN=1 cargo test --test golden_traces`
//!   regenerates the files after an intentional behavior change;
//! * a missing golden file is **pinned on first run** (written, test
//!   passes with a note) so the suite bootstraps itself on the first
//!   machine that has AOT artifacts; CI diffs every run after that.
//!
//! Needs artifacts (`make artifacts`); skips gracefully without them,
//! like every PJRT-dependent test.

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]


use heroes::baselines::{make_strategy, Strategy};
use heroes::codec::json::Json;
use heroes::codec::CodecCfg;
use heroes::config::{ExperimentConfig, QuorumKnob, Scale};
use heroes::coordinator::env::FlEnv;
use heroes::coordinator::quorum_ctl::QuorumPolicy;
use heroes::coordinator::round::RoundDriver;
use heroes::coordinator::RoundReport;
use heroes::runtime::{EnginePool, Manifest};
use heroes::simulation::Scenario;
use heroes::util::rng::Rng;
use std::path::PathBuf;

fn pool_or_skip() -> Option<EnginePool> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(EnginePool::new(Manifest::load(&dir).unwrap(), 2).unwrap())
}

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

/// The pinned run shape: tiny fleet, 2 rounds, eval every round.
fn tiny_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("cnn", Scale::Smoke);
    cfg.n_clients = 8;
    cfg.k_per_round = 4;
    cfg.samples_per_client = 32;
    cfg.test_samples = 128;
    cfg.tau_default = 3;
    cfg.tau_max = 12;
    cfg.rounds = 2;
    cfg.eval_every = 1;
    cfg.workers = 2;
    cfg
}

/// An f64 pinned exactly: bit pattern + readable value.
fn pinned_f64(v: f64) -> Json {
    Json::obj(vec![
        ("bits", Json::Str(format!("{:016x}", v.to_bits()))),
        ("value", Json::Num(v)),
    ])
}

/// Fingerprint one report series: per eval point (every round here) the
/// cumulative simulated clock, cumulative traffic and the K the round
/// actually aggregated.
fn fingerprint(reports: &[RoundReport]) -> Json {
    let mut sim_time = 0.0f64;
    let mut bytes = 0u64;
    let rows = reports
        .iter()
        .map(|r| {
            sim_time += r.round_time;
            bytes += r.down_bytes + r.up_bytes;
            Json::obj(vec![
                ("round", Json::from(r.round)),
                ("sim_time", pinned_f64(sim_time)),
                ("traffic_gb", pinned_f64(bytes as f64 / 1e9)),
                ("chosen_k", Json::from(r.completion_times.len())),
            ])
        })
        .collect();
    Json::Arr(rows)
}

/// Run `scheme` for the pinned 2 rounds under `scenario`/`quorum`/
/// `codec` and fingerprint the series.
fn run_fingerprint(
    pool: &EnginePool,
    scheme: &str,
    scenario: &str,
    quorum: QuorumKnob,
    codec: CodecCfg,
) -> Json {
    let mut cfg = tiny_cfg();
    cfg.scenario = Scenario::parse(scenario).unwrap();
    cfg.quorum = quorum;
    cfg.codec = codec;
    let mut env = FlEnv::build(pool, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut strategy = make_strategy(scheme, &env.info, &cfg, &mut rng).unwrap();
    let driver = RoundDriver::new(cfg.workers);
    let reports = if let Some(mut policy) = QuorumPolicy::from_config(&cfg) {
        driver
            .run_quorum(pool, &mut env, strategy.as_mut(), cfg.rounds, &mut policy, None)
            .unwrap()
    } else {
        (0..cfg.rounds).map(|_| strategy.run_round(&mut env).unwrap()).collect()
    };
    fingerprint(&reports)
}

#[test]
fn golden_traces_pin_the_round_pipeline() {
    let Some(pool) = pool_or_skip() else { return };
    let regen = std::env::var("HEROES_REGEN_GOLDEN").ok().as_deref() == Some("1");
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    // Self-bootstrap is allowed only when NO goldens exist yet (the
    // growth container could not generate the seed baseline). Once any
    // golden is committed, a missing file means accidental deletion —
    // failing there, instead of silently re-pinning current behavior,
    // is the whole point of the harness.
    let bootstrap = !std::fs::read_dir(&dir).unwrap().any(|e| {
        e.map(|e| e.path().extension().and_then(|x| x.to_str()) == Some("json"))
            .unwrap_or(false)
    });

    for scheme in ["heroes", "fedavg", "flanc"] {
        // one stable synchronous run and one churned adaptive-quorum run
        // per scheme family — the two pipelines the acceptance criteria
        // care about
        let doc = Json::obj(vec![
            ("scheme", Json::from(scheme)),
            (
                "stable",
                run_fingerprint(&pool, scheme, "stable", QuorumKnob::Off, CodecCfg::Analytic),
            ),
            (
                "churn_quorum_auto",
                run_fingerprint(
                    &pool,
                    scheme,
                    "correlated-dropout",
                    QuorumKnob::Auto,
                    CodecCfg::Analytic,
                ),
            ),
        ]);
        let path = dir.join(format!("{scheme}.json"));
        if regen || (bootstrap && !path.exists()) {
            std::fs::write(&path, doc.to_string_pretty()).unwrap();
            eprintln!(
                "{} golden trace {}",
                if regen { "regenerated" } else { "pinned new" },
                path.display()
            );
            continue;
        }
        assert!(
            path.exists(),
            "golden trace {} is missing while sibling goldens exist — restore it from git, \
             or regenerate the whole set with HEROES_REGEN_GOLDEN=1 and review the diff",
            path.display()
        );
        let want = heroes::codec::json::parse_file(&path).unwrap();
        assert_eq!(
            doc, want,
            "{scheme}: golden trace drifted from {} — if the change is intentional, \
             regenerate with HEROES_REGEN_GOLDEN=1 and review the diff",
            path.display()
        );
    }
}

#[test]
fn wire_q8_golden_trace_pins_the_codec_path() {
    // the quantized wire pipeline gets its own golden: same fingerprint
    // schema, `--codec wire:q8` billing. Bootstraps **per file** — this
    // golden was introduced after the original set, so it must pin
    // itself on the first artifact-bearing machine even when sibling
    // goldens already exist (the all-or-nothing bootstrap above only
    // fires on a pristine tree).
    let Some(pool) = pool_or_skip() else { return };
    let regen = std::env::var("HEROES_REGEN_GOLDEN").ok().as_deref() == Some("1");
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let q8 = CodecCfg::parse("wire:q8").unwrap();
    let doc = Json::obj(vec![
        ("scheme", Json::from("heroes")),
        ("codec", Json::from(q8.name().as_str())),
        ("stable", run_fingerprint(&pool, "heroes", "stable", QuorumKnob::Off, q8)),
        (
            "churn_quorum_auto",
            run_fingerprint(&pool, "heroes", "correlated-dropout", QuorumKnob::Auto, q8),
        ),
    ]);
    let path = dir.join("heroes_wire_q8.json");
    if regen || !path.exists() {
        std::fs::write(&path, doc.to_string_pretty()).unwrap();
        eprintln!(
            "{} golden trace {}",
            if regen { "regenerated" } else { "pinned new" },
            path.display()
        );
        return;
    }
    let want = heroes::codec::json::parse_file(&path).unwrap();
    assert_eq!(
        doc, want,
        "wire:q8 golden trace drifted from {} — if the change is intentional, \
         regenerate with HEROES_REGEN_GOLDEN=1 and review the diff",
        path.display()
    );
}

/// `run_fingerprint` with a fault schedule riding the run: the pinned
/// retry policy keeps its budget at the severity cap so every
/// retry-class fault recovers (the golden exercises recovery delays,
/// not cohort loss), and `workers` is explicit so the golden can assert
/// its own worker-count invariance before pinning bytes.
fn run_faulted_fingerprint(pool: &EnginePool, workers: usize) -> Json {
    use heroes::coordinator::resilience::FaultPolicyCfg;
    use heroes::simulation::{FaultsCfg, MAX_SEVERITY};
    let mut cfg = tiny_cfg();
    cfg.workers = workers;
    cfg.faults = FaultsCfg::parse("exec=0.4,corrupt=0.3,partition=0.4").unwrap();
    cfg.fault_policy = FaultPolicyCfg { budget: MAX_SEVERITY, ..FaultPolicyCfg::default() };
    let mut env = FlEnv::build(pool, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut strategy = make_strategy("heroes", &env.info, &cfg, &mut rng).unwrap();
    let driver = RoundDriver::new(cfg.workers);
    let reports: Vec<RoundReport> =
        (0..cfg.rounds).map(|_| strategy.run_round(&mut env).unwrap()).collect();
    let mut doc = fingerprint(&reports);
    // the resilience ledger is part of the pinned surface: counter
    // drift (a fault drawn or resolved differently) fails the diff
    if let Json::Arr(rows) = &mut doc {
        rows.push(Json::obj(vec![("resilience", env.resilience().to_json())]));
    }
    doc
}

#[test]
fn faulted_golden_trace_pins_the_resilience_path() {
    // the fault-injection pipeline gets its own golden: the pinned
    // fingerprint plus the run's resilience ledger. Bootstraps per file
    // (same discipline as the wire:q8 golden — introduced after the
    // original set), and asserts worker-count invariance *before*
    // pinning, so the golden can never freeze a racy byte.
    let Some(pool) = pool_or_skip() else { return };
    let regen = std::env::var("HEROES_REGEN_GOLDEN").ok().as_deref() == Some("1");
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let w1 = run_faulted_fingerprint(&pool, 1);
    let w2 = run_faulted_fingerprint(&pool, 2);
    assert_eq!(w1, w2, "a faulted run's fingerprint must not depend on the worker count");
    let doc = Json::obj(vec![
        ("scheme", Json::from("heroes")),
        ("faults", Json::from("exec=0.4,corrupt=0.3,partition=0.4")),
        ("fault_policy", Json::from("retry, budget=MAX_SEVERITY")),
        ("stable", w1),
    ]);
    let path = dir.join("heroes_faulted.json");
    if regen || !path.exists() {
        std::fs::write(&path, doc.to_string_pretty()).unwrap();
        eprintln!(
            "{} golden trace {}",
            if regen { "regenerated" } else { "pinned new" },
            path.display()
        );
        return;
    }
    let want = heroes::codec::json::parse_file(&path).unwrap();
    assert_eq!(
        doc, want,
        "faulted golden trace drifted from {} — if the change is intentional, \
         regenerate with HEROES_REGEN_GOLDEN=1 and review the diff",
        path.display()
    );
}

/// Cumulative traffic (GB) at the last fingerprinted eval point.
fn final_traffic_gb(fp: &Json) -> f64 {
    fp.as_arr()
        .unwrap()
        .last()
        .unwrap()
        .get("traffic_gb")
        .unwrap()
        .get("value")
        .unwrap()
        .as_f64()
        .unwrap()
}

#[test]
fn wire_q8_bills_strictly_less_traffic_than_analytic() {
    // the acceptance criterion in one test: same seed, same plan shape,
    // but the q8 frames are smaller than the analytic float count, so
    // the meter must bill strictly less
    let Some(pool) = pool_or_skip() else { return };
    let analytic =
        run_fingerprint(&pool, "heroes", "stable", QuorumKnob::Off, CodecCfg::Analytic);
    let q8 = run_fingerprint(
        &pool,
        "heroes",
        "stable",
        QuorumKnob::Off,
        CodecCfg::parse("wire:q8").unwrap(),
    );
    let (a, w) = (final_traffic_gb(&analytic), final_traffic_gb(&q8));
    assert!(w < a, "wire:q8 must bill strictly less than analytic ({w} !< {a})");
}

#[test]
fn fingerprints_are_reproducible_within_a_process() {
    // the harness's own determinism: two identical runs fingerprint
    // identically (otherwise golden diffs would be noise)
    let Some(pool) = pool_or_skip() else { return };
    let a = run_fingerprint(
        &pool,
        "fedavg",
        "correlated-dropout",
        QuorumKnob::Auto,
        CodecCfg::Analytic,
    );
    let b = run_fingerprint(
        &pool,
        "fedavg",
        "correlated-dropout",
        QuorumKnob::Auto,
        CodecCfg::Analytic,
    );
    assert_eq!(a, b, "golden fingerprints must be reproducible");

    // and the wire pipeline inherits the same reproducibility
    let q8 = CodecCfg::parse("wire:q8").unwrap();
    let c = run_fingerprint(&pool, "heroes", "stable", QuorumKnob::Off, q8);
    let d = run_fingerprint(&pool, "heroes", "stable", QuorumKnob::Off, q8);
    assert_eq!(c, d, "wire:q8 fingerprints must be reproducible");
}
