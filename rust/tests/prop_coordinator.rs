//! Property-based tests (prop-lite) over the coordinator's pure logic:
//! block ledger balance, round-planner invariants, aggregation
//! conservation, partitioner correctness, the scenario engine's
//! schedule invariants (trace bounds, window monotonicity, schedule
//! purity, non-quorum-dropout merge invariance), and the lazy
//! population model (sparse ≡ dense cohort sampling, derivation
//! purity, the O(cohort) materialization bound). None of these need
//! artifacts.

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]


use heroes::coordinator::aggregate::{ComposedAccumulator, DenseAccumulator};
use heroes::coordinator::assignment::{plan_round, ClientStatus, ControllerCfg};
use heroes::coordinator::frequency::{completion_time, tau_bounds, Estimates};
use heroes::coordinator::ledger::BlockLedger;
use heroes::coordinator::quorum_ctl::{QuorumController, QuorumCtlCfg, QuorumSignals};
use heroes::coordinator::round::{quorum_members_surviving, staleness_weight};
use heroes::data::partition::{gamma_partition, phi_partition};
use heroes::model::tests_support::toy_info;
use heroes::model::{ComposedGlobal, DenseGlobal};
use heroes::simulation::network::{MBIT, MIN_BANDWIDTH_SCALE};
use heroes::simulation::population::sparse_sample_distinct;
use heroes::simulation::{
    LazyCache, LinkSample, NetworkModel, Population, PopulationSpec, Scenario, SCENARIO_CATALOG,
};
use heroes::tensor::Tensor;
use heroes::util::prop::check;
use heroes::util::rng::Rng;

fn ctrl() -> ControllerCfg {
    ControllerCfg {
        mu_max: 0.5,
        rho: 0.8,
        eta: 0.1,
        epsilon: 0.8,
        tau_min: 1,
        tau_max: 40,
        tau_floor: 8,
        h_max: 1_000_000,
        beta_sq: 0.0,
        codec: heroes::codec::CodecCfg::Analytic,
    }
}

fn statuses_from(qs: &[f64], ups: &[f64]) -> Vec<ClientStatus> {
    qs.iter()
        .zip(ups)
        .enumerate()
        .map(|(i, (&q, &up))| ClientStatus {
            client: i,
            q_flops: q,
            link: LinkSample { up_bps: up, down_bps: up * 8.0 },
        })
        .collect()
}

#[test]
fn prop_ledger_rotation_balances_counts() {
    // Repeatedly planning rounds keeps the group-count spread bounded:
    // least-trained-first can never let one group run away.
    check(
        11,
        60,
        |rng| {
            let n: usize = 2 + rng.below(6);
            let rounds: usize = 1 + rng.below(12);
            let qs: Vec<f64> = (0..n).map(|_| rng.uniform_in(1e6, 4e7)).collect();
            let ups: Vec<f64> = (0..n).map(|_| rng.uniform_in(3e3, 3e4)).collect();
            (qs, ups, rounds)
        },
        |(qs, ups, rounds)| {
            let info = toy_info();
            let mut ledger = BlockLedger::new(&info).unwrap();
            let est = Estimates { l: 1.5, sigma_sq: 0.4, g_sq: 1.2, loss: 2.0 };
            let mut max_tau = 0u64;
            for _ in 0..*rounds {
                let plan = plan_round(&info, &ctrl(), &est, &statuses_from(qs, ups), &mut ledger)
                    .map_err(|e| e.to_string())?;
                for a in &plan.assignments {
                    max_tau = max_tau.max(a.tau as u64);
                }
            }
            let (lo, hi) = ledger.count_range();
            if hi - lo > max_tau * (qs.len() as u64) * (*rounds as u64) {
                return Err(format!("spread {} exceeds hard bound", hi - lo));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_plan_round_invariants() {
    // For every plan: widths in range, τ in range, blocks consistent with
    // width, every completion within the reference unless pinned.
    check(
        13,
        80,
        |rng| {
            let n: usize = 1 + rng.below(8);
            let qs: Vec<f64> = (0..n).map(|_| rng.uniform_in(5e5, 6e7)).collect();
            let ups: Vec<f64> = (0..n).map(|_| rng.uniform_in(2e3, 5e4)).collect();
            (qs, ups)
        },
        |(qs, ups)| {
            let info = toy_info();
            let cfg = ctrl();
            let mut ledger = BlockLedger::new(&info).unwrap();
            let est = Estimates { l: 2.0, sigma_sq: 0.3, g_sq: 1.0, loss: 2.3 };
            let plan = plan_round(&info, &cfg, &est, &statuses_from(qs, ups), &mut ledger)
                .map_err(|e| e.to_string())?;
            if plan.assignments.len() != qs.len() {
                return Err("lost a client".into());
            }
            for a in &plan.assignments {
                if !(1..=info.cap_p).contains(&a.p) {
                    return Err(format!("width {} out of range", a.p));
                }
                if !(cfg.tau_min..=cfg.tau_max).contains(&a.tau) {
                    return Err(format!("tau {} out of range", a.tau));
                }
                for (li, layer) in info.layers.iter().enumerate() {
                    let expect = layer.blocks_at(a.p);
                    if a.selection.blocks[li].len() != expect {
                        return Err(format!(
                            "layer {li}: {} blocks != b(p)={expect}",
                            a.selection.blocks[li].len()
                        ));
                    }
                    let mut sorted = a.selection.blocks[li].clone();
                    sorted.sort_unstable();
                    sorted.dedup();
                    if sorted != a.selection.blocks[li] {
                        return Err("blocks not ascending/unique".into());
                    }
                }
                let t = completion_time(a.tau, a.mu, a.nu);
                if (t - a.projected_t).abs() > 1e-9 {
                    return Err("projected_t inconsistent".into());
                }
                if t > plan.t_l + 1e-9 && a.tau > cfg.tau_min {
                    return Err(format!("client {} exceeds T_l without being pinned", a.client));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tau_bounds_respect_eq24() {
    check(
        17,
        300,
        |rng| {
            let t_l = rng.uniform_in(0.1, 100.0);
            let mu = rng.uniform_in(0.01, 5.0);
            let nu = rng.uniform_in(0.0, 20.0);
            let rho = rng.uniform_in(0.0, 5.0);
            (vec![t_l, mu, nu], rho)
        },
        |(v, rho)| {
            let (t_l, mu, nu) = (v[0], v[1], v[2]);
            let (lo, hi) = tau_bounds(t_l, mu, nu, *rho, 1, 1000);
            if lo > hi {
                return Err(format!("empty bracket [{lo},{hi}]"));
            }
            for tau in [lo, hi] {
                let t = completion_time(tau, mu, nu);
                let slack = t_l - t;
                let clamped = tau == 1 || tau == 1000;
                if !clamped && (slack < -1e-9 || slack > rho + mu + 1e-9) {
                    return Err(format!("τ={tau}: slack {slack} violates Eq. 24 (ρ={rho})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_composed_aggregation_idempotent() {
    // If all clients upload exactly what they received, aggregation
    // returns the previous global unchanged, for any block selections.
    check(
        19,
        60,
        |rng| (1 + rng.below(5), rng.next_u64()),
        |&(k, seed)| {
            let info = toy_info();
            let mut rng = Rng::new(seed);
            let prev = ComposedGlobal::init(&info, &mut rng).unwrap();
            let mut ledger = BlockLedger::new(&info).unwrap();
            let mut acc = ComposedAccumulator::new(&info, &prev);
            for i in 0..k {
                let p = 1 + (i % info.cap_p);
                let sel = ledger.select_for_width(&info, p).unwrap();
                ledger.record(&sel, 1).unwrap();
                let payload = prev.reduced_inputs(&info, p, &sel.blocks).unwrap();
                acc.push(&sel.blocks, &payload).unwrap();
            }
            let next = acc.finalize().unwrap();
            for (a, b) in next.coeffs.iter().zip(&prev.coeffs) {
                if a.sq_dist(b) > 1e-8 {
                    return Err("coefficient changed under identical uploads".into());
                }
            }
            for (a, b) in next.bases.iter().zip(&prev.bases) {
                if a.sq_dist(b) > 1e-8 {
                    return Err("basis changed under identical uploads".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dense_bias_is_plain_average() {
    check(
        23,
        50,
        |rng| (1 + rng.below(4), rng.next_u64()),
        |&(k, seed)| {
            let info = toy_info();
            let mut rng = Rng::new(seed);
            let prev = DenseGlobal::init(&info, &mut rng).unwrap();
            let mut acc = DenseAccumulator::new(&info, &prev);
            let mut uploads = Vec::new();
            for i in 0..k {
                let p = 1 + (i % info.cap_p);
                let mut up = prev.reduced_inputs(&info, p).unwrap();
                for t in up.iter_mut() {
                    let delta = Tensor::randn(t.shape(), 0.1, &mut rng);
                    t.add_assign(&delta);
                }
                acc.push(p, &up).unwrap();
                uploads.push(up);
            }
            let next = acc.finalize().unwrap();
            let expect: f32 =
                uploads.iter().map(|u| u.last().unwrap().data()[0]).sum::<f32>() / k as f32;
            let got = next.bias.data()[0];
            if (got - expect).abs() > 1e-4 {
                return Err(format!("bias avg {got} != {expect}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_staleness_weights_positive_and_monotone() {
    // For any α ≥ 0 the late-merge weight 1/(1+s)^α is positive, at most
    // 1, equals 1 at s = 0, and is monotone non-increasing in s (strictly
    // decreasing for α > 0).
    check(
        37,
        200,
        |rng| (rng.uniform_in(0.0, 4.0), rng.below(30)),
        |&(alpha, s_max)| {
            let w0 = staleness_weight(0, alpha);
            if (w0 - 1.0).abs() > 1e-7 {
                return Err(format!("w(0) = {w0} != 1"));
            }
            let mut prev = w0;
            for s in 1..=s_max + 1 {
                let w = staleness_weight(s, alpha);
                if w <= 0.0 {
                    return Err(format!("w({s}) = {w} not positive at α={alpha}"));
                }
                if w > prev + 1e-9 {
                    return Err(format!("w({s}) = {w} > w({}) = {prev} at α={alpha}", s - 1));
                }
                if alpha > 0.05 && w >= prev {
                    return Err(format!("w not strictly decreasing at s={s}, α={alpha}"));
                }
                prev = w;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quorum_weights_normalize_per_block() {
    // A quorum round's aggregate is an affine combination per block: for
    // clients pushing constant-valued updates vᵢ at weights wᵢ, every
    // trained block must equal Σ wᵢvᵢ / Σ wᵢ (effective weights sum to
    // 1), every untouched block must carry the previous global, and the
    // basis must equal the all-participant weighted mean.
    check(
        41,
        60,
        |rng| {
            let k = 1 + rng.below(5);
            let weights: Vec<f64> = (0..k).map(|_| rng.uniform_in(0.05, 1.0)).collect();
            let values: Vec<f64> = (0..k).map(|_| rng.uniform_in(-3.0, 3.0)).collect();
            (weights, values, rng.next_u64())
        },
        |(weights, values, seed)| {
            let info = toy_info();
            let mut rng = Rng::new(*seed);
            let prev = ComposedGlobal::init(&info, &mut rng).unwrap();
            let mut ledger = BlockLedger::new(&info).unwrap();
            let mut acc = ComposedAccumulator::new(&info, &prev);

            // expected per-block numerator/denominator in f64
            let blocks_l0 = info.layers[0].blocks_total;
            let mut num = vec![0.0f64; blocks_l0];
            let mut den = vec![0.0f64; blocks_l0];
            let mut basis_num = 0.0f64;
            let mut wsum = 0.0f64;

            for (i, (&w, &v)) in weights.iter().zip(values).enumerate() {
                let p = 1 + (i % info.cap_p);
                let sel = ledger.select_for_width(&info, p).unwrap();
                ledger.record(&sel, 1).unwrap();
                let payload: Vec<_> = prev
                    .reduced_inputs(&info, p, &sel.blocks)
                    .unwrap()
                    .iter()
                    .map(|t| Tensor::from_vec(t.shape(), vec![v as f32; t.len()]))
                    .collect();
                acc.push_weighted(&sel.blocks, &payload, w as f32)
                    .map_err(|e| e.to_string())?;
                for &b in &sel.blocks[0] {
                    num[b] += w * v;
                    den[b] += w;
                }
                basis_num += w * v;
                wsum += w;
            }
            let next = acc.finalize().map_err(|e| e.to_string())?;

            // layer-0 coefficient blocks: trained ⇒ Σwv/Σw, untouched ⇒ prev
            let o = info.layers[0].o;
            let u = next.coeffs[0].data();
            let u_prev = prev.coeffs[0].data();
            let cols = info.layers[0].full_coeff_shape()[1];
            let rows = info.layers[0].full_coeff_shape()[0];
            for b in 0..blocks_l0 {
                for row in 0..rows {
                    for c in 0..o {
                        let idx = row * cols + b * o + c;
                        if den[b] > 0.0 {
                            let expect = num[b] / den[b];
                            if (u[idx] as f64 - expect).abs() > 1e-4 {
                                return Err(format!(
                                    "block {b}: {} != Σwv/Σw = {expect}",
                                    u[idx]
                                ));
                            }
                        } else if u[idx] != u_prev[idx] {
                            return Err(format!("untouched block {b} drifted"));
                        }
                    }
                }
            }
            // basis: all participants train it ⇒ weighted mean everywhere
            let expect_basis = basis_num / wsum;
            for &x in next.bases[0].data() {
                if (x as f64 - expect_basis).abs() > 1e-4 {
                    return Err(format!("basis {x} != weighted mean {expect_basis}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dense_weighted_idempotent_for_any_weights() {
    // Pushing the previous global back at arbitrary positive weights must
    // return it unchanged — the element-wise effective weights normalize
    // to 1 whatever the staleness discounts were.
    check(
        43,
        50,
        |rng| {
            let k = 1 + rng.below(4);
            let ws: Vec<f64> = (0..k).map(|_| rng.uniform_in(0.05, 1.0)).collect();
            (ws, rng.next_u64())
        },
        |(ws, seed)| {
            let info = toy_info();
            let mut rng = Rng::new(*seed);
            let prev = DenseGlobal::init(&info, &mut rng).unwrap();
            let mut acc = DenseAccumulator::new(&info, &prev);
            for (i, &w) in ws.iter().enumerate() {
                let p = 1 + (i % info.cap_p);
                let up = prev.reduced_inputs(&info, p).unwrap();
                acc.push_weighted(p, &up, w as f32).map_err(|e| e.to_string())?;
            }
            let next = acc.finalize().map_err(|e| e.to_string())?;
            for (a, b) in next.weights.iter().zip(&prev.weights) {
                if a.sq_dist(b) > 1e-8 {
                    return Err("weights drifted under identical weighted uploads".into());
                }
            }
            if next.bias.sq_dist(&prev.bias) > 1e-8 {
                return Err("bias drifted under identical weighted uploads".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_adaptive_k_stays_in_range() {
    // For any completions, signals and knobs, the controller's K lands
    // in [k_min.clamp(1, n), n] and its α in [alpha_min, alpha_max] —
    // over a whole sequence of decisions, not just the first.
    check(
        47,
        120,
        |rng| {
            let n = 1 + rng.below(20);
            let completions: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.1, 30.0)).collect();
            let knobs = vec![
                rng.uniform_in(0.05, 1.0),  // margin_frac
                rng.uniform_in(0.0, 3.0),   // alpha_max
                rng.uniform_in(0.0, 0.6),   // staleness_index
                rng.uniform_in(0.0, 2.0),   // beta_sq
                rng.uniform_in(0.1, 10.0),  // l
                rng.uniform_in(0.0, 2.0),   // spread_index
                rng.uniform_in(0.0, 1.0),   // dropout_rate
            ];
            (completions, knobs, 1 + rng.below(8)) // k_min
        },
        |(completions, knobs, k_min)| {
            if completions.is_empty() {
                return Ok(()); // shrinking artifact; rejected upstream
            }
            let n = completions.len();
            let mut cfg = QuorumCtlCfg::new(0.8, *k_min, knobs[0], knobs[1]);
            cfg.spread_min = 0.05;
            let mut ctl = QuorumController::new(cfg);
            let sig = QuorumSignals {
                staleness_index: knobs[2],
                beta_sq: knobs[3],
                l: knobs[4],
                spread_index: knobs[5],
                dropout_rate: knobs[6],
                fault_rate: 0.0,
            };
            let lo = (*k_min).clamp(1, n);
            for _ in 0..5 {
                let d = ctl.decide(completions, &sig);
                if d.k < lo || d.k > n {
                    return Err(format!("K = {} escaped [{lo}, {n}]", d.k));
                }
                if !(0.0..=knobs[1]).contains(&d.alpha) {
                    return Err(format!("α = {} escaped [0, {}]", d.alpha, knobs[1]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_adaptive_k_monotone_in_staleness() {
    // At fixed α (annealing frozen), the chosen K is monotone
    // non-decreasing in the observed staleness index: losses already on
    // the books shrink the budget, so the controller can only demand
    // *more* synchrony, never less.
    check(
        53,
        120,
        |rng| {
            let n = 2 + rng.below(18);
            let completions: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.1, 30.0)).collect();
            (completions, rng.uniform_in(0.0, 2.0))
        },
        |(completions, alpha)| {
            if completions.is_empty() {
                return Ok(()); // shrinking artifact; rejected upstream
            }
            let mut cfg = QuorumCtlCfg::new(0.8, 1, 0.5, *alpha);
            cfg.alpha_gain = 0.0; // isolate the K rule
            let mut prev = 0usize;
            for step in 0..=10 {
                let sig = QuorumSignals {
                    staleness_index: step as f64 * 0.02,
                    ..QuorumSignals::default()
                };
                let mut ctl = QuorumController::new(cfg);
                let d = ctl.decide(completions, &sig);
                if d.k < prev {
                    return Err(format!(
                        "K shrank from {prev} to {} as staleness rose to {}",
                        d.k,
                        step as f64 * 0.02
                    ));
                }
                prev = d.k;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_adaptive_collapses_without_a_straggler_tail() {
    // Any cohort whose projected completions all sit within the spread
    // threshold of the maximum decides K = N — the provable collapse to
    // the full-barrier path — regardless of the observed signals.
    check(
        59,
        120,
        |rng| {
            let n = 1 + rng.below(20);
            let base = rng.uniform_in(0.5, 20.0);
            // all completions within 4% of the max: under spread_min 5%
            let completions: Vec<f64> =
                (0..n).map(|_| base * rng.uniform_in(0.96, 1.0)).collect();
            let sig = vec![
                rng.uniform_in(0.0, 0.5),
                rng.uniform_in(0.0, 1.0),
                rng.uniform_in(0.1, 10.0),
                rng.uniform_in(0.0, 1.0),
            ];
            (completions, sig)
        },
        |(completions, s)| {
            if completions.is_empty() {
                return Ok(()); // shrinking artifact; rejected upstream
            }
            let mut ctl = QuorumController::new(QuorumCtlCfg::new(0.8, 1, 0.5, 1.0));
            let sig = QuorumSignals {
                staleness_index: s[0],
                beta_sq: s[1],
                l: s[2],
                spread_index: s[3],
                ..QuorumSignals::default()
            };
            let d = ctl.decide(completions, &sig);
            if d.k != completions.len() {
                return Err(format!(
                    "no-tail cohort decided K = {} instead of N = {}",
                    d.k,
                    completions.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scenario_traces_stay_within_declared_bounds() {
    // For any catalog scenario and seed: every trace multiplier lands in
    // [MIN_BANDWIDTH_SCALE, 1], and a link sampled under it stays inside
    // the scaled band — a trace can starve the WAN, never corrupt it.
    check(
        61,
        60,
        |rng| (rng.next_u64(), rng.below(SCENARIO_CATALOG.len())),
        |&(seed, which)| {
            let s = Scenario::parse(SCENARIO_CATALOG[which]).map_err(|e| e.to_string())?;
            let Some(trace) = s.bandwidth_trace(seed) else {
                return Ok(()); // scenario does not drift bandwidth
            };
            let model = NetworkModel::default();
            let mut rng = heroes::util::rng::Rng::new(seed ^ 0xBEEF);
            for round in 0..2 * s.period_rounds() {
                let m = trace.scale(round);
                if !(MIN_BANDWIDTH_SCALE..=1.0).contains(&m) {
                    return Err(format!("round {round}: multiplier {m} escaped the band"));
                }
                let link = model.sample_scaled(&mut rng, m);
                let (lo, hi) = (model.up_lo_mbps * MBIT * m, model.up_hi_mbps * MBIT * m);
                // tolerance pads the band edges against multiplication
                // rounding (the sample scales after drawing)
                if link.up_bps < lo * (1.0 - 1e-12) || link.up_bps > hi * (1.0 + 1e-12) {
                    return Err(format!(
                        "round {round}: up {} outside scaled band [{lo}, {hi}]",
                        link.up_bps
                    ));
                }
                if !link.upload_time(1_000_000).is_finite() {
                    return Err("scaled link leaked a non-finite transfer time".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scenario_availability_windows_are_monotone_on_the_clock() {
    // Availability is a single cyclic window per period on the round
    // axis (rounds are monotone on the virtual clock): at most two
    // transitions per period, and the schedule repeats exactly.
    check(
        67,
        60,
        |rng| (rng.next_u64(), rng.below(SCENARIO_CATALOG.len()), rng.below(40)),
        |&(seed, which, client)| {
            let s = Scenario::parse(SCENARIO_CATALOG[which]).map_err(|e| e.to_string())?;
            let period = s.period_rounds();
            let window: Vec<bool> = (0..period).map(|r| s.available(seed, client, r)).collect();
            let transitions =
                (0..period).filter(|&r| window[r] != window[(r + 1) % period]).count();
            if transitions > 2 {
                return Err(format!(
                    "client {client}: {transitions} transitions in one {period}-round period"
                ));
            }
            for r in 0..period {
                if s.available(seed, client, r + period) != window[r] {
                    return Err(format!("round {r}: schedule is not {period}-round periodic"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scenario_schedule_is_pure_for_any_evaluation_order() {
    // Same seed ⇒ identical schedule for any --workers/--pool: every
    // schedule quantity is a pure function of (scenario, seed, round,
    // client), so recomputing entries in a shuffled order reproduces the
    // forward sweep exactly — there is no hidden cursor for a worker
    // count to perturb.
    check(
        71,
        40,
        |rng| (rng.next_u64(), rng.below(SCENARIO_CATALOG.len()), rng.next_u64()),
        |&(seed, which, shuffle_seed)| {
            let s = Scenario::parse(SCENARIO_CATALOG[which]).map_err(|e| e.to_string())?;
            let cells: Vec<(usize, usize)> =
                (0..30).flat_map(|r| (0..8).map(move |c| (r, c))).collect();
            let forward: Vec<_> = cells
                .iter()
                .map(|&(r, c)| (s.available(seed, c, r), s.dropout(seed, r, c)))
                .collect();
            let mut order: Vec<usize> = (0..cells.len()).collect();
            heroes::util::rng::Rng::new(shuffle_seed).shuffle(&mut order);
            for &i in &order {
                let (r, c) = cells[i];
                let again = (s.available(seed, c, r), s.dropout(seed, r, c));
                if again != forward[i] {
                    return Err(format!(
                        "(round {r}, client {c}): schedule changed on re-evaluation"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dropout_of_non_quorum_member_never_changes_the_merge() {
    // The quorum member set — and therefore the merged bytes, which are
    // a function of exactly the members' updates (aggregation props
    // above) — is invariant under dropping any client outside it.
    check(
        73,
        120,
        |rng| {
            let n = 2 + rng.below(18);
            let completions: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.1, 30.0)).collect();
            (completions, rng.next_u64(), 1 + rng.below(8))
        },
        |(completions, mask_seed, k)| {
            let n = completions.len();
            if n == 0 {
                return Ok(()); // shrinking artifact; rejected upstream
            }
            let k = (*k).clamp(1, n);
            let none = vec![false; n];
            let members = quorum_members_surviving(completions, &none, k);
            // drop a random subset of the NON-members only
            let mut rng = heroes::util::rng::Rng::new(*mask_seed);
            let mut mask = vec![false; n];
            for i in 0..n {
                if !members.contains(&i) && rng.uniform() < 0.5 {
                    mask[i] = true;
                }
            }
            let with_churn = quorum_members_surviving(completions, &mask, k);
            if with_churn != members {
                return Err(format!(
                    "members changed under non-member churn: {members:?} -> {with_churn:?}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_adaptive_k_monotone_in_dropout_rate() {
    // At fixed α, the controller's K is monotone non-decreasing in the
    // observed dropout rate: churn consumes the staleness budget like
    // realized losses, so the controller can only demand more synchrony.
    check(
        79,
        120,
        |rng| {
            let n = 2 + rng.below(18);
            let completions: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.1, 30.0)).collect();
            (completions, rng.uniform_in(0.0, 2.0))
        },
        |(completions, alpha)| {
            if completions.is_empty() {
                return Ok(()); // shrinking artifact; rejected upstream
            }
            let mut cfg = QuorumCtlCfg::new(0.8, 1, 0.5, *alpha);
            cfg.alpha_gain = 0.0; // isolate the K rule
            let mut prev = 0usize;
            for step in 0..=10 {
                let sig = QuorumSignals {
                    dropout_rate: step as f64 * 0.05,
                    ..QuorumSignals::default()
                };
                let mut ctl = QuorumController::new(cfg);
                let d = ctl.decide(completions, &sig);
                if d.k < prev {
                    return Err(format!(
                        "K shrank from {prev} to {} as the dropout rate rose to {}",
                        d.k,
                        step as f64 * 0.05
                    ));
                }
                prev = d.k;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gamma_partition_invariants() {
    check(
        29,
        40,
        |rng| {
            let classes = 2 + rng.below(10);
            let clients = 1 + rng.below(10);
            let quota = 5 + rng.below(30);
            let gamma = rng.uniform_in(100.0 / classes as f64, 95.0);
            (vec![classes, clients, quota], gamma)
        },
        |(v, gamma)| {
            let (classes, clients, quota) = (v[0], v[1], v[2]);
            let n = classes * clients * quota; // plenty of samples
            let labels: Vec<i32> = (0..n).map(|i| (i % classes) as i32).collect();
            let mut rng = Rng::new(7);
            let plan = gamma_partition(&labels, classes, clients, quota, *gamma, &mut rng);
            if plan.n_clients() != clients {
                return Err("lost a client".into());
            }
            let mut seen = std::collections::HashSet::new();
            for c in 0..plan.n_clients() {
                let p = plan.client_indices(c);
                if p.len() != quota || plan.shard_len(c) != quota {
                    return Err("quota violated".into());
                }
                for &i in &p {
                    if !seen.insert(i) {
                        return Err(format!("duplicate sample {i}"));
                    }
                    if i >= n {
                        return Err("index out of range".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_phi_partition_missing_classes() {
    check(
        31,
        40,
        |rng| {
            let classes = 4 + rng.below(16);
            let missing = rng.below(classes - 1);
            let clients = 1 + rng.below(6);
            (classes, missing, clients)
        },
        |&(classes, missing, clients)| {
            let quota = 40;
            let n = classes * clients * quota; // ample
            let labels: Vec<i32> = (0..n).map(|i| (i % classes) as i32).collect();
            let mut rng = Rng::new(9);
            let plan = phi_partition(&labels, classes, clients, quota, missing, &mut rng);
            for c in 0..plan.n_clients() {
                let mut present = vec![false; classes];
                for &i in &plan.client_indices(c) {
                    present[labels[i] as usize] = true;
                }
                let held = present.iter().filter(|&&x| x).count();
                if held > classes - missing {
                    return Err(format!("client holds {held} > {} classes", classes - missing));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sparse_cohort_sampler_is_bit_identical_to_dense() {
    // The O(k) sparse Fisher–Yates consumes exactly the `below(n - i)`
    // draw sequence of Rng::sample_distinct: for any (n, k, seed) the
    // output AND the residual RNG state are identical — the population
    // sampler is a pure optimization, not a new distribution.
    check(
        83,
        200,
        |rng| {
            let n = 1 + rng.below(5000);
            let k = rng.below(n + 1).min(64);
            (n, k, rng.next_u64())
        },
        |&(n, k, seed)| {
            let mut dense_rng = Rng::new(seed ^ 0x5EED);
            let mut sparse_rng = Rng::new(seed ^ 0x5EED);
            let dense = dense_rng.sample_distinct(n, k);
            let sparse = sparse_sample_distinct(n, k, &mut sparse_rng);
            if sparse != dense {
                return Err(format!("n={n} k={k}: sparse {sparse:?} != dense {dense:?}"));
            }
            if dense_rng.next_u64() != sparse_rng.next_u64() {
                return Err(format!("n={n} k={k}: residual RNG state diverged"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_population_derivations_are_pure_for_any_evaluation_order() {
    // Every per-client quantity is a fresh keyed RNG — no shared cursor —
    // so re-deriving (class, flops, link draw, shard spec) in a shuffled
    // order, with repeats, reproduces the forward sweep bit for bit.
    // This is the invariant that makes the bounded cache's evictions
    // invisible and lazy runs independent of cohort touch order.
    check(
        89,
        40,
        |rng| (rng.next_u64(), rng.next_u64(), 2 + rng.below(6)),
        |&(seed, shuffle_seed, rounds)| {
            let pop = Population::new(PopulationSpec::default_mix(100_000, seed)).unwrap();
            let net = NetworkModel::default();
            let cells: Vec<(usize, usize)> = (0..rounds)
                .flat_map(|r| pop.sample_cohort(r, 8, |_| true).into_iter().map(move |c| (r, c)))
                .collect();
            let derive = |&(r, c): &(usize, usize)| {
                let link = net.sample(&mut pop.link_rng(c, r));
                (
                    pop.device_class(c).name(),
                    pop.flops(c, r).to_bits(),
                    link.up_bps.to_bits(),
                    pop.shard_spec(c, 60),
                )
            };
            let forward: Vec<_> = cells.iter().map(derive).collect();
            let mut order: Vec<usize> = (0..cells.len()).collect();
            Rng::new(shuffle_seed).shuffle(&mut order);
            for &i in order.iter().chain(order.iter().rev()) {
                if derive(&cells[i]) != forward[i] {
                    return Err(format!(
                        "(round {}, client {}): derivation changed on re-evaluation",
                        cells[i].0, cells[i].1
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lazy_rounds_materialize_o_cohort_not_o_population() {
    // The acceptance bound behind `--population lazy`: driving R rounds
    // of K-client cohorts against a 100 000-client population through a
    // bounded LazyCache touches at most R·K client states (at most one
    // materialization per cohort slot — re-sampled clients may hit) and
    // never holds more than the O(cohort) capacity resident. Nothing
    // here depends on the population size, which is the point.
    check(
        97,
        20,
        |rng| (rng.next_u64(), 2 + rng.below(4), 4 + rng.below(29)),
        |&(seed, rounds, k)| {
            let population = 100_000usize;
            let pop = Population::new(PopulationSpec::default_mix(population, seed)).unwrap();
            let capacity = 4 * k;
            let mut cache: LazyCache<u64> = LazyCache::new(capacity).unwrap();
            for round in 0..rounds {
                let cohort = pop.sample_cohort(round, k, |_| true);
                if cohort.len() != k {
                    return Err(format!("round {round}: cohort {} != {k}", cohort.len()));
                }
                for &c in &cohort {
                    // stand-in for shard synthesis: a pure function of the
                    // client's shard spec (cheap, so 20 cases stay fast)
                    let spec = pop.shard_spec(c, 60);
                    let v = cache.get_or_insert_with(c, || spec.seed ^ spec.quota as u64);
                    if v != spec.seed ^ spec.quota as u64 {
                        return Err(format!("client {c}: cache returned a foreign value"));
                    }
                }
                if cache.resident() > capacity {
                    return Err(format!("resident {} > capacity {capacity}", cache.resident()));
                }
            }
            let st = cache.stats();
            if st.materializations > rounds * k {
                return Err(format!(
                    "{} materializations > rounds·K = {} at population {population}",
                    st.materializations,
                    rounds * k
                ));
            }
            if st.peak_resident > capacity {
                return Err(format!("peak resident {} > capacity {capacity}", st.peak_resident));
            }
            Ok(())
        },
    );
}
