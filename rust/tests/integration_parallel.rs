//! The parallel round pipeline's contracts (see `coordinator::round` and
//! `runtime::pool` module docs):
//!
//! 1. **Determinism** — a seeded run emits byte-identical `RoundReport`
//!    sequences for `--workers 1`, `--workers 4` (shared engine *and*
//!    per-worker engine pool) and for overlapped dispatch, across all
//!    three scheme families (Heroes, dense, Flanc).
//! 2. **Engine pool** — per-engine executable caches are isolated,
//!    merged stats sum over engines, `prepare_all` warms every shard.
//! 3. **Thread safety** — one `Engine` serves concurrent `execute` calls
//!    (the `Sync` bound is also pinned at compile time).
//! 4. **Semi-async quorum** — `--quorum N` (full cohort) is byte-
//!    identical to the serial loop for every scheme family; `--quorum
//!    K<N` is seed-deterministic for any worker count and closes rounds
//!    at the K-th projected completion instead of the cohort maximum.
//! 5. **Adaptive quorum** — `--quorum auto` is seed-deterministic for
//!    any worker count, keeps every round's K within `[K_floor, N]`,
//!    and on a homogeneous cohort (no straggler tail) collapses to the
//!    full-barrier path byte-identically.
//!
//! PJRT-dependent tests require `make artifacts` and skip gracefully
//! otherwise.

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]


use heroes::baselines::{make_strategy, Strategy};
use heroes::config::{DropoutPolicy, ExperimentConfig, Scale};
use heroes::coordinator::env::FlEnv;
use heroes::coordinator::quorum_ctl::{QuorumController, QuorumCtlCfg, QuorumPolicy};
use heroes::coordinator::round::RoundDriver;
use heroes::coordinator::RoundReport;
use heroes::model::ComposedGlobal;
use heroes::runtime::{Engine, EnginePool, Manifest};
use heroes::simulation::{ClientDevice, DeviceClass, Scenario, ScenarioError};
use heroes::util::rng::Rng;

fn pool_or_skip(engines: usize) -> Option<EnginePool> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(EnginePool::new(Manifest::load(&dir).unwrap(), engines).unwrap())
}

fn tiny_cfg(workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("cnn", Scale::Smoke);
    cfg.n_clients = 8;
    cfg.k_per_round = 4;
    cfg.samples_per_client = 32;
    cfg.test_samples = 128;
    cfg.tau_default = 3;
    cfg.tau_max = 12;
    cfg.workers = workers;
    cfg
}

/// Run `rounds` rounds of `scheme` through the per-round (non-overlapped)
/// path, returning the report series plus the final (loss, accuracy).
fn run_reports(
    pool: &EnginePool,
    cfg: &ExperimentConfig,
    scheme: &str,
    rounds: usize,
) -> (Vec<RoundReport>, (f64, f64)) {
    let mut env = FlEnv::build(pool, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy(scheme, &env.info, cfg, &mut rng).unwrap();
    let reports = (0..rounds).map(|_| s.run_round(&mut env).unwrap()).collect();
    (reports, s.evaluate(&env).unwrap())
}

/// Same rounds through `RoundDriver::run_overlapped` (straggler-
/// overlapped planning over a persistent worker pool).
fn run_reports_overlapped(
    pool: &EnginePool,
    cfg: &ExperimentConfig,
    scheme: &str,
    rounds: usize,
) -> (Vec<RoundReport>, (f64, f64)) {
    let mut env = FlEnv::build(pool, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy(scheme, &env.info, cfg, &mut rng).unwrap();
    let driver = RoundDriver::new(cfg.workers);
    let reports = driver.run_overlapped(pool, &mut env, s.as_mut(), rounds).unwrap();
    (reports, s.evaluate(&env).unwrap())
}

/// Same rounds through `RoundDriver::run_quorum` under an arbitrary
/// quorum policy (static K or the adaptive controller); `doctor` runs
/// against the freshly-built env before anything executes, so tests can
/// shape the fleet (homogeneous / skewed) identically across runs.
fn run_reports_policy(
    pool: &EnginePool,
    cfg: &ExperimentConfig,
    scheme: &str,
    rounds: usize,
    mut policy: QuorumPolicy,
    doctor: impl Fn(&mut FlEnv),
) -> (Vec<RoundReport>, (f64, f64)) {
    let mut env = FlEnv::build(pool, cfg.clone()).unwrap();
    doctor(&mut env);
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy(scheme, &env.info, cfg, &mut rng).unwrap();
    let driver = RoundDriver::new(cfg.workers);
    let reports =
        driver.run_quorum(pool, &mut env, s.as_mut(), rounds, &mut policy, None).unwrap();
    (reports, s.evaluate(&env).unwrap())
}

/// Same rounds through `RoundDriver::run_quorum` (semi-async K-of-N
/// aggregation with staleness-weighted late merges).
fn run_reports_quorum(
    pool: &EnginePool,
    cfg: &ExperimentConfig,
    scheme: &str,
    rounds: usize,
    quorum: usize,
    alpha: f64,
) -> (Vec<RoundReport>, (f64, f64)) {
    run_reports_policy(pool, cfg, scheme, rounds, QuorumPolicy::fixed(quorum, alpha), |_| {})
}

#[test]
fn engine_type_is_shareable_across_threads() {
    // no artifacts needed: a pure compile-time pin of the Sync bound the
    // round driver's scoped workers rely on
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Engine>();
    assert_send_sync::<EnginePool>();
}

#[test]
fn reports_identical_across_workers_pool_and_overlap() {
    // The acceptance pin: for every scheme family, workers=1 (serial),
    // workers=4 on a shared engine, workers=4 on a per-worker pool, and
    // workers=4 overlapped must all produce byte-identical report series
    // and final models.
    let Some(shared) = pool_or_skip(1) else { return };
    let Some(pooled) = pool_or_skip(4) else { return };
    for scheme in ["heroes", "fedavg", "flanc"] {
        let rounds = 3;
        let (serial, eval_serial) = run_reports(&shared, &tiny_cfg(1), scheme, rounds);
        let (threads, eval_threads) = run_reports(&shared, &tiny_cfg(4), scheme, rounds);
        let (pool4, eval_pool4) = run_reports(&pooled, &tiny_cfg(4), scheme, rounds);
        let (overlap, eval_overlap) = run_reports_overlapped(&pooled, &tiny_cfg(4), scheme, rounds);
        assert_eq!(serial, threads, "{scheme}: workers must not change rounds");
        assert_eq!(serial, pool4, "{scheme}: the engine pool must not change rounds");
        assert_eq!(serial, overlap, "{scheme}: overlapped dispatch must not change rounds");
        assert_eq!(eval_serial, eval_threads, "{scheme}: workers changed the final model");
        assert_eq!(eval_serial, eval_pool4, "{scheme}: the pool changed the final model");
        assert_eq!(eval_serial, eval_overlap, "{scheme}: overlap changed the final model");
    }
}

#[test]
fn full_quorum_matches_serial_for_every_scheme_family() {
    // The acceptance pin: `--quorum N` (K = the whole cohort) must
    // reproduce the serial loop's RoundReport sequence and final model
    // byte-identically for Heroes, dense and Flanc alike — no stragglers
    // exist, so every round routes through the synchronous phase C.
    let Some(shared) = pool_or_skip(1) else { return };
    let Some(pooled) = pool_or_skip(4) else { return };
    for scheme in ["heroes", "fedavg", "flanc"] {
        let rounds = 3;
        let (serial, eval_serial) = run_reports(&shared, &tiny_cfg(1), scheme, rounds);
        let (quorum, eval_quorum) =
            run_reports_quorum(&pooled, &tiny_cfg(4), scheme, rounds, 4, 1.0);
        assert_eq!(serial, quorum, "{scheme}: full quorum must not change rounds");
        assert_eq!(eval_serial, eval_quorum, "{scheme}: full quorum changed the final model");
        // quorum larger than the cohort clamps to the cohort — same bytes
        let (over, eval_over) = run_reports_quorum(&pooled, &tiny_cfg(4), scheme, rounds, 99, 1.0);
        assert_eq!(serial, over, "{scheme}: oversized quorum must clamp to full barrier");
        assert_eq!(eval_serial, eval_over);
    }
}

#[test]
fn partial_quorum_is_deterministic_for_any_worker_count() {
    // K < N: the round closes at the K-th projected completion and
    // stragglers merge late — deterministically, because membership and
    // merge timing live on the virtual clock, not on thread racing.
    let Some(shared) = pool_or_skip(1) else { return };
    let Some(pooled) = pool_or_skip(4) else { return };
    for scheme in ["heroes", "fedavg", "flanc"] {
        let rounds = 4;
        let (q1, eval1) = run_reports_quorum(&shared, &tiny_cfg(1), scheme, rounds, 2, 1.0);
        let (q4, eval4) = run_reports_quorum(&pooled, &tiny_cfg(4), scheme, rounds, 2, 1.0);
        let (q4b, eval4b) = run_reports_quorum(&pooled, &tiny_cfg(4), scheme, rounds, 2, 1.0);
        assert_eq!(q1, q4, "{scheme}: quorum rounds must not depend on worker count");
        assert_eq!(q4, q4b, "{scheme}: quorum rounds must be reproducible");
        assert_eq!(eval1, eval4, "{scheme}: final model must not depend on worker count");
        assert_eq!(eval4, eval4b, "{scheme}: final model must be reproducible");

        // and it genuinely is semi-async: every round reports exactly K
        // quorum completions, and round 0 (identical plans across modes)
        // closes no later than the full barrier
        let (serial, _) = run_reports(&shared, &tiny_cfg(1), scheme, 1);
        assert_eq!(q1[0].completion_times.len(), 2, "{scheme}: quorum round reports K members");
        assert!(
            q1[0].round_time <= serial[0].round_time + 1e-12,
            "{scheme}: quorum round 0 must close no later than the full barrier \
             ({} > {})",
            q1[0].round_time,
            serial[0].round_time
        );
    }
}

/// Serial (per-round, full-barrier) reference with the same env
/// doctoring hook as `run_reports_policy`.
fn run_reports_serial_doctored(
    pool: &EnginePool,
    cfg: &ExperimentConfig,
    scheme: &str,
    rounds: usize,
    doctor: impl Fn(&mut FlEnv),
) -> (Vec<RoundReport>, (f64, f64)) {
    let mut env = FlEnv::build(pool, cfg.clone()).unwrap();
    doctor(&mut env);
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy(scheme, &env.info, cfg, &mut rng).unwrap();
    let reports = (0..rounds).map(|_| s.run_round(&mut env).unwrap()).collect();
    (reports, s.evaluate(&env).unwrap())
}

/// The adaptive policy as `--quorum auto` would build it from the smoke
/// preset (ε = 0.8, floor 1, margin 0.5, α ceiling 1).
fn auto_policy() -> QuorumPolicy {
    QuorumPolicy::Auto(QuorumController::new(QuorumCtlCfg::new(0.8, 1, 0.5, 1.0)))
}

/// A provably homogeneous cohort: full participation keeps every
/// identically-seeded device's per-round draw in lockstep, and the
/// degenerate WAN band makes every link sample identical — so all
/// projected completions coincide and no straggler tail can exist.
fn homo_cfg(workers: usize) -> ExperimentConfig {
    let mut cfg = tiny_cfg(workers);
    cfg.k_per_round = cfg.n_clients;
    cfg.up_mbps = (2.0 / 30.0, 2.0 / 30.0);
    cfg.down_mbps = (15.0 / 30.0, 15.0 / 30.0);
    // pinning the τ range makes every controller hand every
    // identical-status client the same τ (the Eq. 24 bracket clamps to a
    // point), so completions coincide exactly — no float-rounding edge
    // can fabricate a spread
    cfg.tau_min = cfg.tau_default;
    cfg.tau_max = cfg.tau_default;
    cfg
}

fn make_homogeneous(env: &mut FlEnv) {
    for d in env.fleet.devices.iter_mut() {
        *d = ClientDevice::new(DeviceClass::AgxXavier, Rng::new(7));
    }
}

/// The bench's straggler tail: client 0 on a ~4.5× slower device.
fn make_skewed(env: &mut FlEnv) {
    for (i, d) in env.fleet.devices.iter_mut().enumerate() {
        let class = if i == 0 { DeviceClass::Laptop } else { DeviceClass::AgxXavier };
        *d = ClientDevice::new(class, Rng::new(100 + i as u64));
    }
}

#[test]
fn adaptive_quorum_homogeneous_cohort_matches_full_barrier() {
    // The acceptance pin: `--quorum auto` on a cohort with no straggler
    // tail must decide K = N every round, route through the synchronous
    // phase-C hook, and reproduce the full-barrier run byte-identically
    // — for every scheme family.
    let Some(shared) = pool_or_skip(1) else { return };
    let Some(pooled) = pool_or_skip(4) else { return };
    for scheme in ["heroes", "fedavg", "flanc"] {
        let rounds = 3;
        let (serial, eval_serial) =
            run_reports_serial_doctored(&shared, &homo_cfg(1), scheme, rounds, make_homogeneous);
        let (adaptive, eval_adaptive) = run_reports_policy(
            &pooled,
            &homo_cfg(4),
            scheme,
            rounds,
            auto_policy(),
            make_homogeneous,
        );
        assert_eq!(
            serial, adaptive,
            "{scheme}: adaptive quorum on a homogeneous cohort must be the full barrier"
        );
        assert_eq!(
            eval_serial, eval_adaptive,
            "{scheme}: adaptive quorum changed the final model on a homogeneous cohort"
        );
        let n = homo_cfg(1).k_per_round;
        for r in &adaptive {
            assert_eq!(
                r.completion_times.len(),
                n,
                "{scheme}: a no-tail round must aggregate the whole cohort"
            );
        }
    }
}

#[test]
fn adaptive_quorum_is_deterministic_for_any_worker_count() {
    // Adaptive decisions read only virtual-clock state (plan facts +
    // ledger signals), so a straggler-tailed `--quorum auto` run must be
    // byte-identical across worker/pool counts and reproducible, with
    // every round's K inside [floor, cohort].
    let Some(shared) = pool_or_skip(1) else { return };
    let Some(pooled) = pool_or_skip(4) else { return };
    for scheme in ["heroes", "fedavg", "flanc"] {
        let rounds = 4;
        let (a1, e1) =
            run_reports_policy(&shared, &tiny_cfg(1), scheme, rounds, auto_policy(), make_skewed);
        let (a4, e4) =
            run_reports_policy(&pooled, &tiny_cfg(4), scheme, rounds, auto_policy(), make_skewed);
        let (a4b, e4b) =
            run_reports_policy(&pooled, &tiny_cfg(4), scheme, rounds, auto_policy(), make_skewed);
        assert_eq!(a1, a4, "{scheme}: adaptive rounds must not depend on worker count");
        assert_eq!(a4, a4b, "{scheme}: adaptive rounds must be reproducible");
        assert_eq!(e1, e4, "{scheme}: final model must not depend on worker count");
        assert_eq!(e4, e4b, "{scheme}: final model must be reproducible");
        let cohort = tiny_cfg(1).k_per_round;
        for r in &a1 {
            let k = r.completion_times.len();
            assert!(
                (1..=cohort).contains(&k),
                "{scheme}: adaptive K = {k} escaped [1, {cohort}] at round {}",
                r.round
            );
        }
    }
}

#[test]
fn heroes_reports_identical_for_workers_1_and_4() {
    let Some(pool) = pool_or_skip(1) else { return };
    let (serial, eval1) = run_reports(&pool, &tiny_cfg(1), "heroes", 4);
    let (parallel, eval4) = run_reports(&pool, &tiny_cfg(4), "heroes", 4);
    assert_eq!(serial, parallel, "heroes rounds must not depend on worker count");
    assert_eq!(eval1, eval4, "final model must not depend on worker count");
}

#[test]
fn dense_baseline_reports_identical_for_workers_1_and_4() {
    let Some(pool) = pool_or_skip(2) else { return };
    for scheme in ["fedavg", "heterofl"] {
        let (serial, eval1) = run_reports(&pool, &tiny_cfg(1), scheme, 4);
        let (parallel, eval4) = run_reports(&pool, &tiny_cfg(4), scheme, 4);
        assert_eq!(serial, parallel, "{scheme} rounds must not depend on worker count");
        assert_eq!(eval1, eval4, "{scheme} final model must not depend on worker count");
    }
}

#[test]
fn flanc_reports_identical_for_workers_1_and_4() {
    let Some(pool) = pool_or_skip(1) else { return };
    let (serial, _) = run_reports(&pool, &tiny_cfg(1), "flanc", 3);
    let (parallel, _) = run_reports(&pool, &tiny_cfg(4), "flanc", 3);
    assert_eq!(serial, parallel, "flanc rounds must not depend on worker count");
}

#[test]
fn pool_caches_are_isolated_and_stats_merge() {
    // Compiling on one engine must not touch its siblings' caches; the
    // pool's stats are the sum of the shards.
    let Some(pool) = pool_or_skip(2) else { return };
    let name = Manifest::train_name("cnn", 1, true);
    pool.engine(0).prepare(&name).unwrap();
    let s0 = pool.engine(0).stats();
    let s1 = pool.engine(1).stats();
    assert!(s0.compiles >= 1, "engine 0 must have compiled {name}");
    assert_eq!(s1.compiles, 0, "engine 1's cache must stay cold");
    let merged = pool.stats();
    assert_eq!(merged.compiles, s0.compiles + s1.compiles);
    assert_eq!(merged.executions, s0.executions + s1.executions);

    // prepare_all warms every shard; a second call is a no-op (cached)
    pool.prepare_all(&[name.as_str()]).unwrap();
    assert!(pool.engine(1).stats().compiles >= 1, "prepare_all must warm engine 1");
    let warmed = pool.stats().compiles;
    pool.prepare_all(&[name.as_str()]).unwrap();
    assert_eq!(pool.stats().compiles, warmed, "warm caches must not recompile");
}

#[test]
fn pool_engines_execute_identically() {
    // The determinism contract's engine-independence leg: one train step
    // with identical inputs is bit-identical on every engine of the pool
    // (same HLO, same compile pipeline, same CPU).
    let Some(pool) = pool_or_skip(3) else { return };
    let info = pool.manifest().model("cnn").unwrap().clone();
    let mut rng = Rng::new(2);
    let global = ComposedGlobal::init(&info, &mut rng).unwrap();
    let ledger = heroes::coordinator::ledger::BlockLedger::new(&info).unwrap();
    let sel = ledger.select_for_width(&info, 1).unwrap();
    let params = global.reduced_inputs(&info, 1, &sel.blocks).unwrap();

    let ds = heroes::data::synth_image::ImageGen::cifar_twin().generate(info.batch, 7, &mut rng);
    let ss = ds.sample_size();
    let mut x = vec![0.0f32; info.batch * ss];
    let mut y = vec![0i32; info.batch];
    for i in 0..info.batch {
        x[i * ss..(i + 1) * ss].copy_from_slice(ds.sample(i));
        y[i] = ds.labels[i];
    }
    let xt = heroes::tensor::Tensor::from_vec(&[info.batch, ds.hw, ds.hw, ds.channels], x);
    let yt = heroes::tensor::IntTensor::from_vec(&[info.batch], y);
    let lr = heroes::tensor::Tensor::from_vec(&[1], vec![0.05]);

    let name = Manifest::train_name("cnn", 1, true);
    let outs: Vec<Vec<heroes::tensor::Tensor>> = (0..pool.len())
        .map(|w| {
            let mut inputs: Vec<heroes::runtime::Value> =
                params.iter().map(heroes::runtime::Value::F32).collect();
            inputs.push(heroes::runtime::Value::F32(&xt));
            inputs.push(heroes::runtime::Value::I32(&yt));
            inputs.push(heroes::runtime::Value::F32(&lr));
            pool.engine(w).execute(&name, &inputs).unwrap()
        })
        .collect();
    for (w, o) in outs.iter().enumerate().skip(1) {
        assert_eq!(o.len(), outs[0].len());
        for (a, b) in o.iter().zip(&outs[0]) {
            assert_eq!(a.data(), b.data(), "engine {w} diverged from engine 0");
        }
    }
}

#[test]
fn empty_cohort_dispatch_is_an_error() {
    // no artifacts needed: the driver rejects an empty round before it
    // ever touches an engine... but constructing an EnginePool needs a
    // client, so gate on artifacts anyway.
    let Some(pool) = pool_or_skip(1) else { return };
    let driver = RoundDriver::new(4);
    let err = driver.run(&pool, Vec::new()).unwrap_err();
    assert!(err.to_string().contains("empty cohort"), "unexpected error: {err}");
}

#[test]
fn two_threads_execute_on_one_engine_concurrently() {
    let Some(pool) = pool_or_skip(1) else { return };
    let cfg = tiny_cfg(1);
    let env = FlEnv::build(&pool, cfg.clone()).unwrap();
    let global = ComposedGlobal::init(&env.info, &mut Rng::new(cfg.seed)).unwrap();

    // serial reference, also warms the eval executable's compile cache
    let reference = env.evaluate_composed(&global).unwrap();

    // hammer the same engine from several threads at once; every thread
    // must see exactly the serial result
    let results: Vec<(f64, f64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| s.spawn(|| env.evaluate_composed(&global).unwrap()))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for r in results {
        assert_eq!(r, reference, "concurrent execution must match serial");
    }
}

#[test]
fn scenario_stable_is_byte_identical_to_default() {
    // The acceptance pin: `--scenario stable` (however the dropout policy
    // is set) schedules nothing — its runs reproduce the default path's
    // report series and final model byte for byte, through the
    // overlapped pipeline and the quorum pipeline alike.
    let Some(shared) = pool_or_skip(1) else { return };
    let Some(pooled) = pool_or_skip(4) else { return };
    for scheme in ["heroes", "fedavg", "flanc"] {
        let rounds = 3;
        let (default_run, eval_default) = run_reports(&shared, &tiny_cfg(1), scheme, rounds);
        for policy in [DropoutPolicy::Survivors, DropoutPolicy::Error] {
            let mut cfg = tiny_cfg(4);
            cfg.scenario = Scenario::parse("stable").unwrap();
            cfg.dropout_policy = policy;
            let (explicit, eval_explicit) = run_reports_overlapped(&pooled, &cfg, scheme, rounds);
            assert_eq!(
                default_run, explicit,
                "{scheme}: --scenario stable ({policy:?}) must not change rounds"
            );
            assert_eq!(
                eval_default, eval_explicit,
                "{scheme}: --scenario stable ({policy:?}) changed the final model"
            );
        }
        let mut cfg = tiny_cfg(4);
        cfg.scenario = Scenario::parse("stable").unwrap();
        let (quorum, eval_quorum) =
            run_reports_policy(&pooled, &cfg, scheme, rounds, QuorumPolicy::fixed(4, 1.0), |_| {});
        assert_eq!(default_run, quorum, "{scheme}: stable must be inert on the quorum path");
        assert_eq!(eval_default, eval_quorum);
    }
}

#[test]
fn dropout_of_non_quorum_client_changes_nothing() {
    // The acceptance pin: a mid-round dropout of a client outside the
    // quorum changes neither the merged model bytes nor the run's exit
    // status. Full participation + skewed fleet puts client 0 (the
    // ~4.5× straggler) outside every K=4-of-8 quorum; dropping it in the
    // last round — where its late merge would fall past the run end
    // anyway — must leave the whole series and the final model
    // byte-identical.
    let Some(shared) = pool_or_skip(1) else { return };
    let Some(pooled) = pool_or_skip(4) else { return };
    let full = |workers: usize| {
        let mut c = tiny_cfg(workers);
        c.k_per_round = c.n_clients;
        c
    };
    let rounds = 4;
    let quorum4 = || QuorumPolicy::fixed(4, 1.0);
    for scheme in ["heroes", "fedavg"] {
        let (base, eval_base) =
            run_reports_policy(&shared, &full(1), scheme, rounds, quorum4(), make_skewed);
        let mut cfg = full(1);
        cfg.scenario = Scenario::Pinned { round: rounds - 1, client: 0, frac: 0.5 };
        let (churn, eval_churn) =
            run_reports_policy(&shared, &cfg, scheme, rounds, quorum4(), make_skewed);
        assert_eq!(base, churn, "{scheme}: a non-quorum dropout must not change any report");
        assert_eq!(eval_base, eval_churn, "{scheme}: a non-quorum dropout changed the model");

        // and the churned run is seed-deterministic for any worker count
        let mut cfg4 = full(4);
        cfg4.scenario = cfg.scenario;
        let (churn4, eval4) =
            run_reports_policy(&pooled, &cfg4, scheme, rounds, quorum4(), make_skewed);
        assert_eq!(churn, churn4, "{scheme}: churned rounds must not depend on worker count");
        assert_eq!(eval_churn, eval4);
    }
}

#[test]
fn churn_that_breaks_quorum_feasibility_is_a_typed_error() {
    let Some(pool) = pool_or_skip(2) else { return };
    // static K = the whole cohort, but one member vanishes in round 1:
    // the barrier can never fill — a typed QuorumInfeasible, not a hang
    // or a silent degrade
    let mut cfg = tiny_cfg(2);
    cfg.k_per_round = cfg.n_clients; // full participation: client 0 is in every round
    cfg.scenario = Scenario::Pinned { round: 1, client: 0, frac: 0.3 };
    let mut env = FlEnv::build(&pool, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy("fedavg", &env.info, &cfg, &mut rng).unwrap();
    let mut policy = QuorumPolicy::fixed(cfg.n_clients, 1.0);
    let err = RoundDriver::new(cfg.workers)
        .run_quorum(&pool, &mut env, s.as_mut(), 3, &mut policy, None)
        .unwrap_err();
    match err.downcast_ref::<ScenarioError>() {
        Some(&ScenarioError::QuorumInfeasible { round, required, survivors }) => {
            assert_eq!((round, required, survivors), (1, 8, 7), "wrong infeasibility facts");
        }
        other => panic!("expected QuorumInfeasible, got {other:?} ({err})"),
    }

    // availability churn starves static K the same way: flash-crowd
    // windows keep the crowd third away from rounds 0..8, so a demanded
    // K = 8 can never fill from the ~5 attending clients — typed error,
    // not a silent clamp to the thinned cohort
    let mut cfg = tiny_cfg(2);
    cfg.k_per_round = cfg.n_clients;
    cfg.scenario = Scenario::parse("flash-crowd-churn").unwrap();
    let mut env = FlEnv::build(&pool, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy("fedavg", &env.info, &cfg, &mut rng).unwrap();
    let mut policy = QuorumPolicy::fixed(cfg.n_clients, 1.0);
    let err = RoundDriver::new(cfg.workers)
        .run_quorum(&pool, &mut env, s.as_mut(), 2, &mut policy, None)
        .unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<ScenarioError>(),
            Some(&ScenarioError::QuorumInfeasible { round: 0, required: 8, .. })
        ),
        "an availability-thinned cohort must starve static K with a typed error: {err}"
    );

    // a round that drops everyone is EmptySurvivors on the quorum path...
    let mut cfg = tiny_cfg(1);
    cfg.scenario = Scenario::CorrelatedDropout { base: 1.0, burst_every: 0, burst_rate: 1.0 };
    let mut env = FlEnv::build(&pool, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy("fedavg", &env.info, &cfg, &mut rng).unwrap();
    let mut policy = QuorumPolicy::fixed(2, 1.0);
    let err = RoundDriver::new(1)
        .run_quorum(&pool, &mut env, s.as_mut(), 2, &mut policy, None)
        .unwrap_err();
    assert_eq!(
        err.downcast_ref::<ScenarioError>(),
        Some(&ScenarioError::EmptySurvivors { round: 0 }),
        "unexpected error: {err}"
    );

    // ...and on the full-barrier path under the survivors policy, while
    // the error policy surfaces the dropout itself
    let mut env = FlEnv::build(&pool, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy("fedavg", &env.info, &cfg, &mut rng).unwrap();
    let err = s.run_round(&mut env).unwrap_err();
    assert_eq!(
        err.downcast_ref::<ScenarioError>(),
        Some(&ScenarioError::EmptySurvivors { round: 0 }),
        "unexpected error: {err}"
    );
    let mut cfg_err = cfg.clone();
    cfg_err.dropout_policy = DropoutPolicy::Error;
    let mut env = FlEnv::build(&pool, cfg_err.clone()).unwrap();
    let mut rng = Rng::new(cfg_err.seed ^ 0x5EED);
    let mut s = make_strategy("fedavg", &env.info, &cfg_err, &mut rng).unwrap();
    let err = s.run_round(&mut env).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<ScenarioError>(),
            Some(&ScenarioError::MidRoundDropout { round: 0, .. })
        ),
        "unexpected error: {err}"
    );
}

#[test]
fn churn_catalog_runs_are_deterministic_for_any_worker_count() {
    // Every catalog scenario, through the adaptive quorum pipeline and
    // the synchronous path alike, is seed-deterministic for any
    // --workers/--pool — schedules are pure functions of
    // (scenario, seed, round, client).
    let Some(shared) = pool_or_skip(1) else { return };
    let Some(pooled) = pool_or_skip(4) else { return };
    for name in ["diurnal-bandwidth", "flash-crowd-churn", "correlated-dropout"] {
        let mk = |workers: usize| {
            let mut c = tiny_cfg(workers);
            c.scenario = Scenario::parse(name).unwrap();
            c
        };
        let rounds = 4;
        let (a, ea) = run_reports_policy(&shared, &mk(1), "heroes", rounds, auto_policy(), |_| {});
        let (b, eb) = run_reports_policy(&pooled, &mk(4), "heroes", rounds, auto_policy(), |_| {});
        assert_eq!(a, b, "{name}: churn rounds must not depend on worker count");
        assert_eq!(ea, eb, "{name}: final model must not depend on worker count");
        let (s1, es1) = run_reports(&shared, &mk(1), "heroes", rounds);
        let (s4, es4) = run_reports(&pooled, &mk(4), "heroes", rounds);
        assert_eq!(s1, s4, "{name}: sync churn rounds must not depend on worker count");
        assert_eq!(es1, es4);
    }

    // the survivors policy on the barrier path: a deterministic pinned
    // dropout aggregates one fewer completion, identically across
    // worker counts and exiting Ok
    let mut cfg1 = tiny_cfg(1);
    cfg1.k_per_round = cfg1.n_clients;
    cfg1.scenario = Scenario::Pinned { round: 1, client: 2, frac: 0.4 };
    let mut cfg4 = cfg1.clone();
    cfg4.workers = 4;
    let (p1, ep1) = run_reports(&shared, &cfg1, "heroes", 3);
    let (p4, ep4) = run_reports(&pooled, &cfg4, "heroes", 3);
    assert_eq!(p1, p4, "survivors re-plan must not depend on worker count");
    assert_eq!(ep1, ep4);
    assert_eq!(
        p1[1].completion_times.len(),
        cfg1.n_clients - 1,
        "the dropped client must be missing from round 1's aggregation"
    );
    assert_eq!(p1[0].completion_times.len(), cfg1.n_clients, "round 0 is untouched");
}

#[test]
fn batch_streams_are_deterministic_and_independent() {
    let Some(pool) = pool_or_skip(1) else { return };
    let env = FlEnv::build(&pool, tiny_cfg(1)).unwrap();
    let grab = |client: usize, round: usize| {
        let mut s = env.batch_stream(client, round).unwrap();
        let (x, y) = s.next_batch();
        let xs = match x {
            heroes::coordinator::XData::Image(t) => t.data().to_vec(),
            heroes::coordinator::XData::Tokens(t) => t.data().iter().map(|&v| v as f32).collect(),
        };
        (xs, y.data().to_vec())
    };
    // same (client, round) ⇒ identical batches; different round or client
    // ⇒ a different stream
    assert_eq!(grab(0, 0), grab(0, 0));
    assert_ne!(grab(0, 0), grab(0, 1));
    assert_ne!(grab(0, 0), grab(1, 0));
}
