//! The parallel round pipeline's two contracts (see
//! `coordinator::round` module docs):
//!
//! 1. **Determinism** — a seeded run emits byte-identical `RoundReport`
//!    sequences for `--workers 1` and `--workers 4`, for Heroes and for
//!    the dense baselines.
//! 2. **Thread safety** — one `Engine` serves concurrent `execute` calls
//!    (the `Sync` bound is also pinned at compile time).
//!
//! PJRT-dependent tests require `make artifacts` and skip gracefully
//! otherwise.

use heroes::baselines::{make_strategy, Strategy};
use heroes::config::{ExperimentConfig, Scale};
use heroes::coordinator::env::FlEnv;
use heroes::coordinator::RoundReport;
use heroes::model::ComposedGlobal;
use heroes::runtime::{Engine, Manifest};
use heroes::util::rng::Rng;

fn engine_or_skip() -> Option<Engine> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(Manifest::load(&dir).unwrap()).unwrap())
}

fn tiny_cfg(workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("cnn", Scale::Smoke);
    cfg.n_clients = 8;
    cfg.k_per_round = 4;
    cfg.samples_per_client = 32;
    cfg.test_samples = 128;
    cfg.tau_default = 3;
    cfg.tau_max = 12;
    cfg.workers = workers;
    cfg
}

/// Run `rounds` rounds of `scheme`, returning the report series plus the
/// final (loss, accuracy).
fn run_reports(
    engine: &Engine,
    cfg: &ExperimentConfig,
    scheme: &str,
    rounds: usize,
) -> (Vec<RoundReport>, (f64, f64)) {
    let mut env = FlEnv::build(engine, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy(scheme, &env.info, cfg, &mut rng).unwrap();
    let reports = (0..rounds).map(|_| s.run_round(&mut env).unwrap()).collect();
    (reports, s.evaluate(&env).unwrap())
}

#[test]
fn engine_type_is_shareable_across_threads() {
    // no artifacts needed: a pure compile-time pin of the Sync bound the
    // round driver's scoped workers rely on
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Engine>();
}

#[test]
fn heroes_reports_identical_for_workers_1_and_4() {
    let Some(engine) = engine_or_skip() else { return };
    let (serial, eval1) = run_reports(&engine, &tiny_cfg(1), "heroes", 4);
    let (parallel, eval4) = run_reports(&engine, &tiny_cfg(4), "heroes", 4);
    assert_eq!(serial, parallel, "heroes rounds must not depend on worker count");
    assert_eq!(eval1, eval4, "final model must not depend on worker count");
}

#[test]
fn dense_baseline_reports_identical_for_workers_1_and_4() {
    let Some(engine) = engine_or_skip() else { return };
    for scheme in ["fedavg", "heterofl"] {
        let (serial, eval1) = run_reports(&engine, &tiny_cfg(1), scheme, 4);
        let (parallel, eval4) = run_reports(&engine, &tiny_cfg(4), scheme, 4);
        assert_eq!(serial, parallel, "{scheme} rounds must not depend on worker count");
        assert_eq!(eval1, eval4, "{scheme} final model must not depend on worker count");
    }
}

#[test]
fn flanc_reports_identical_for_workers_1_and_4() {
    let Some(engine) = engine_or_skip() else { return };
    let (serial, _) = run_reports(&engine, &tiny_cfg(1), "flanc", 3);
    let (parallel, _) = run_reports(&engine, &tiny_cfg(4), "flanc", 3);
    assert_eq!(serial, parallel, "flanc rounds must not depend on worker count");
}

#[test]
fn two_threads_execute_on_one_engine_concurrently() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = tiny_cfg(1);
    let env = FlEnv::build(&engine, cfg.clone()).unwrap();
    let global = ComposedGlobal::init(&env.info, &mut Rng::new(cfg.seed)).unwrap();

    // serial reference, also warms the eval executable's compile cache
    let reference = env.evaluate_composed(&global).unwrap();

    // hammer the same engine from several threads at once; every thread
    // must see exactly the serial result
    let results: Vec<(f64, f64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| s.spawn(|| env.evaluate_composed(&global).unwrap()))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for r in results {
        assert_eq!(r, reference, "concurrent execution must match serial");
    }
}

#[test]
fn batch_streams_are_deterministic_and_independent() {
    let Some(engine) = engine_or_skip() else { return };
    let env = FlEnv::build(&engine, tiny_cfg(1)).unwrap();
    let grab = |client: usize, round: usize| {
        let mut s = env.batch_stream(client, round);
        let (x, y) = s.next_batch();
        let xs = match x {
            heroes::coordinator::XData::Image(t) => t.data().to_vec(),
            heroes::coordinator::XData::Tokens(t) => t.data().iter().map(|&v| v as f32).collect(),
        };
        (xs, y.data().to_vec())
    };
    // same (client, round) ⇒ identical batches; different round or client
    // ⇒ a different stream
    assert_eq!(grab(0, 0), grab(0, 0));
    assert_ne!(grab(0, 0), grab(0, 1));
    assert_ne!(grab(0, 0), grab(1, 0));
}
