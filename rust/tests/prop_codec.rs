//! Property-based tests (prop-lite) over the `HWU1` update codec: exact
//! raw round-trips, the q8 error bound, top-k's stored-entry count, the
//! header/frame length agreement, encoder purity (the size-and-bytes-
//! are-a-pure-function contract behind `--workers`/`--pool`
//! determinism), and decode-never-panics under truncation. Pure rust —
//! none of these need artifacts.

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]


use heroes::codec::{self, quant, wire, Encoding, FrameMeta};
use heroes::tensor::Tensor;
use heroes::util::prop::check;
use heroes::util::rng::Rng;

fn meta() -> FrameMeta {
    FrameMeta { scheme: codec::scheme_id::HEROES, round: 3, client: 11 }
}

/// A random update silhouette: 1–4 tensors of rank 1–3, dims 0–9 (zero
/// dims included on purpose — empty tensors must round-trip too).
fn gen_case(rng: &mut Rng) -> (Vec<Vec<usize>>, u64) {
    let n = 1 + rng.below(4);
    let shapes = (0..n)
        .map(|_| {
            let rank = 1 + rng.below(3);
            (0..rank).map(|_| rng.below(10)).collect()
        })
        .collect();
    (shapes, rng.next_u64())
}

fn tensors_from(shapes: &[Vec<usize>], seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    shapes.iter().map(|s| Tensor::randn(s, 1.0, &mut rng)).collect()
}

/// Every encoding mode a `--codec wire*` knob can produce.
fn all_encodings() -> Vec<Encoding> {
    let mut out = vec![Encoding::default(), Encoding { q8: true, topk: None }];
    for rate in [0.05, 0.25, 1.0] {
        out.push(Encoding { q8: false, topk: Some(rate) });
        out.push(Encoding { q8: true, topk: Some(rate) });
    }
    out
}

#[test]
fn prop_raw_frames_round_trip_bit_exactly() {
    check(101, 80, gen_case, |(shapes, seed)| {
        let ts = tensors_from(shapes, *seed);
        let mut buf = Vec::new();
        codec::encode_update(&mut buf, &meta(), Encoding::default(), &ts)
            .map_err(|e| e.to_string())?;
        let d = codec::decode_update(&buf).map_err(|e| e.to_string())?;
        for (i, (a, b)) in ts.iter().zip(&d.tensors).enumerate() {
            if a.shape() != b.shape() {
                return Err(format!("tensor {i}: shape {:?} != {:?}", a.shape(), b.shape()));
            }
            if a.data() != b.data() {
                return Err(format!("tensor {i}: raw data must round-trip bit-exactly"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_q8_error_is_bounded_by_the_per_tensor_scale() {
    check(102, 80, gen_case, |(shapes, seed)| {
        let ts = tensors_from(shapes, *seed);
        let enc = Encoding { q8: true, topk: None };
        let mut buf = Vec::new();
        codec::encode_update(&mut buf, &meta(), enc, &ts).map_err(|e| e.to_string())?;
        let d = codec::decode_update(&buf).map_err(|e| e.to_string())?;
        for (i, (a, b)) in ts.iter().zip(&d.tensors).enumerate() {
            // the affine grid rounds to the nearest step, so the
            // reconstruction error is at most half the tensor's scale
            let (_, scale, _) = quant::quantize_q8(a.data());
            for (&x, &y) in a.data().iter().zip(b.data()) {
                let err = (x - y).abs();
                if err > 0.5001 * scale + 1e-6 {
                    return Err(format!(
                        "tensor {i}: q8 error {err} exceeds scale/2 = {}",
                        scale / 2.0
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_topk_stores_exactly_k_entries() {
    check(103, 80, gen_case, |(shapes, seed)| {
        let ts = tensors_from(shapes, *seed);
        for rate in [0.05, 0.3, 1.0] {
            for q8 in [false, true] {
                let enc = Encoding { q8, topk: Some(rate) };
                let mut buf = Vec::new();
                codec::encode_update(&mut buf, &meta(), enc, &ts)
                    .map_err(|e| e.to_string())?;
                let d = codec::decode_update(&buf).map_err(|e| e.to_string())?;
                for (i, (t, s)) in ts.iter().zip(&d.sections).enumerate() {
                    let k = quant::k_of(t.len(), rate);
                    if s.stored != k {
                        return Err(format!(
                            "tensor {i} (len {}, rate {rate}, q8 {q8}): stored {} != k {k}",
                            t.len(),
                            s.stored
                        ));
                    }
                    let dense = d.tensors[i].data().iter().filter(|v| **v != 0.0).count();
                    if dense > k {
                        return Err(format!(
                            "tensor {i}: {dense} nonzero reconstructed entries > k {k}"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_declared_length_matches_the_actual_frame() {
    check(104, 60, gen_case, |(shapes, seed)| {
        let ts = tensors_from(shapes, *seed);
        for enc in all_encodings() {
            let mut buf = Vec::new();
            let n = codec::encode_update(&mut buf, &meta(), enc, &ts)
                .map_err(|e| e.to_string())?;
            let planned = codec::frame_len_for_shapes(
                shapes.iter().map(|s| s.as_slice()),
                enc,
            );
            if n != buf.len() || n != planned {
                return Err(format!(
                    "{enc:?}: returned {n}, wrote {}, planned {planned}",
                    buf.len()
                ));
            }
            let h = wire::read_header(&buf).map_err(|e| e.to_string())?;
            if wire::HEADER_LEN + h.body_len as usize != buf.len() {
                return Err(format!(
                    "{enc:?}: header declares {} body bytes, frame carries {}",
                    h.body_len,
                    buf.len() - wire::HEADER_LEN
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_encoded_bytes_are_pure_and_size_is_a_shape_function() {
    // the determinism contract behind `--workers`/`--pool` invariance:
    // the same (plan, update, cfg) always frames to the same bytes, and
    // the frame *length* ignores the data entirely — so billed traffic
    // cannot depend on scheduling
    check(105, 60, gen_case, |(shapes, seed)| {
        let ts = tensors_from(shapes, *seed);
        let other = tensors_from(shapes, seed.wrapping_add(1));
        for enc in all_encodings() {
            let mut a = Vec::new();
            let mut b = Vec::new();
            codec::encode_update(&mut a, &meta(), enc, &ts).map_err(|e| e.to_string())?;
            codec::encode_update(&mut b, &meta(), enc, &ts).map_err(|e| e.to_string())?;
            if a != b {
                return Err(format!("{enc:?}: two encodes of one update differ"));
            }
            let mut c = Vec::new();
            codec::encode_update(&mut c, &meta(), enc, &other).map_err(|e| e.to_string())?;
            if a.len() != c.len() {
                return Err(format!(
                    "{enc:?}: same shapes, different data changed the frame length \
                     ({} vs {})",
                    a.len(),
                    c.len()
                ));
            }
        }
        Ok(())
    });
}

/// A reader that hands out at most `chunk` bytes per `read` call, so a
/// frame arrives split at arbitrary boundaries — the shape a TCP stream
/// actually delivers.
struct Chunked<'a> {
    data: &'a [u8],
    pos: usize,
    chunk: usize,
}

impl std::io::Read for Chunked<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.chunk.min(buf.len()).min(self.data.len() - self.pos);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

#[test]
fn prop_chunked_stream_reads_match_one_shot_decoding() {
    // streaming contract: a frame delivered in arbitrary chunks decodes
    // to the same bytes-and-values as the one-shot slice path, and a
    // truncated stream yields the same *typed* error the slice yields
    // for that prefix (trailing-bytes aside — a stream's surplus belongs
    // to the next frame)
    check(107, 60, gen_case, |(shapes, seed)| {
        let ts = tensors_from(shapes, *seed);
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1));
        for enc in all_encodings() {
            let mut buf = Vec::new();
            codec::encode_update(&mut buf, &meta(), enc, &ts).map_err(|e| e.to_string())?;
            for chunk in [1, 3, 7, wire::HEADER_LEN, buf.len()] {
                let mut r = Chunked { data: &buf, pos: 0, chunk };
                let frame = wire::read_frame_from(&mut r, u64::MAX)
                    .map_err(|e| format!("{enc:?} chunk {chunk}: {e}"))?;
                if frame != buf {
                    return Err(format!(
                        "{enc:?} chunk {chunk}: streamed frame differs from the encoded bytes"
                    ));
                }
                let d = wire::decode_update_from(
                    &mut Chunked { data: &buf, pos: 0, chunk },
                    u64::MAX,
                )
                .map_err(|e| format!("{enc:?} chunk {chunk}: {e}"))?;
                let one_shot = codec::decode_update(&buf).map_err(|e| e.to_string())?;
                for (i, (a, b)) in one_shot.tensors.iter().zip(&d.tensors).enumerate() {
                    if a.shape() != b.shape() || a.data() != b.data() {
                        return Err(format!(
                            "{enc:?} chunk {chunk}: tensor {i} diverges from one-shot decode"
                        ));
                    }
                }
            }
            // truncation parity: cut mid-header and mid-body
            for _ in 0..4 {
                let cut = rng.below(buf.len());
                let stream_err = wire::decode_update_from(
                    &mut Chunked { data: &buf[..cut], pos: 0, chunk: 5 },
                    u64::MAX,
                )
                .err()
                .ok_or_else(|| format!("{enc:?}: {cut}-byte stream prefix decoded"))?;
                let slice_err = codec::decode_update(&buf[..cut])
                    .err()
                    .ok_or_else(|| format!("{enc:?}: {cut}-byte slice prefix decoded"))?;
                let (s, o) = (format!("{stream_err:?}"), format!("{slice_err:?}"));
                if s != o {
                    return Err(format!(
                        "{enc:?} cut {cut}: stream error {s} != one-shot error {o}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_truncated_frames_error_instead_of_panicking() {
    check(106, 60, gen_case, |(shapes, seed)| {
        let ts = tensors_from(shapes, *seed);
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        for enc in all_encodings() {
            let mut buf = Vec::new();
            codec::encode_update(&mut buf, &meta(), enc, &ts).map_err(|e| e.to_string())?;
            for _ in 0..8 {
                let cut = rng.below(buf.len());
                if codec::decode_update(&buf[..cut]).is_ok() {
                    return Err(format!(
                        "{enc:?}: decoding a {cut}-byte prefix of a {}-byte frame \
                         succeeded",
                        buf.len()
                    ));
                }
            }
        }
        Ok(())
    });
}
