//! Integration: the Heroes server end-to-end on tiny federated worlds.
//! Requires `make artifacts` (skips gracefully otherwise).

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]


use heroes::baselines::Strategy;
use heroes::config::{ExperimentConfig, Scale};
use heroes::coordinator::env::FlEnv;
use heroes::coordinator::server::HeroesServer;
use heroes::runtime::{EnginePool, Manifest};
use heroes::util::rng::Rng;

fn engine_or_skip() -> Option<EnginePool> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(EnginePool::single(Manifest::load(&dir).unwrap()).unwrap())
}

fn tiny_cfg(family: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(family, Scale::Smoke);
    cfg.n_clients = 8;
    cfg.k_per_round = 4;
    cfg.samples_per_client = 32;
    cfg.test_samples = 128;
    cfg.shard_tokens = 800;
    cfg.tau_default = 4;
    cfg.tau_max = 12;
    cfg
}

#[test]
fn heroes_cnn_rounds_run_and_improve() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = tiny_cfg("cnn");
    let mut env = FlEnv::build(&engine, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed);
    let mut server = HeroesServer::new(&env.info, &cfg, &mut rng).unwrap();

    let (loss0, acc0) = server.evaluate(&env).unwrap();
    assert!(acc0 < 0.35, "untrained accuracy should be near chance, got {acc0}");

    let mut reports = Vec::new();
    for _ in 0..10 {
        reports.push(server.run_round(&mut env).unwrap());
    }
    let (loss1, acc1) = server.evaluate(&env).unwrap();
    assert!(loss1 < loss0, "test loss should drop: {loss0} -> {loss1}");
    assert!(acc1 > acc0, "accuracy should improve: {acc0} -> {acc1}");

    // structural checks on the reports
    for r in &reports {
        assert_eq!(r.taus.len(), cfg.k_per_round);
        assert_eq!(r.widths.len(), cfg.k_per_round);
        assert!(r.widths.iter().all(|&p| (1..=4).contains(&p)));
        assert!(r.taus.iter().all(|&t| (1..=cfg.tau_max).contains(&t)));
        assert!(r.round_time > 0.0);
        assert!(r.avg_wait >= 0.0);
        assert!(r.down_bytes > 0 && r.up_bytes > 0);
    }
    // clock advanced by the sum of round times; traffic metered
    let total: f64 = reports.iter().map(|r| r.round_time).sum();
    assert!((env.clock.now() - total).abs() < 1e-9);
    assert_eq!(
        env.traffic.total_bytes(),
        reports.iter().map(|r| r.down_bytes + r.up_bytes).sum::<u64>()
    );
}

#[test]
fn heroes_adapts_taus_after_bootstrap() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = tiny_cfg("cnn");
    let mut env = FlEnv::build(&engine, cfg.clone()).unwrap();
    let mut rng = Rng::new(7);
    let mut server = HeroesServer::new(&env.info, &cfg, &mut rng).unwrap();

    // round 0: bootstrap — identical predefined τ
    let r0 = server.run_round(&mut env).unwrap();
    assert!(r0.taus.iter().all(|&t| t == cfg.tau_default), "round 0 must use τ_default");

    // later rounds: controller active, τ diversity expected across
    // heterogeneous clients (paper Fig. 2b)
    let mut diverse = false;
    for _ in 0..6 {
        let r = server.run_round(&mut env).unwrap();
        let min = r.taus.iter().min().unwrap();
        let max = r.taus.iter().max().unwrap();
        if max > min {
            diverse = true;
        }
    }
    assert!(diverse, "adaptive τ should differ across heterogeneous clients");
}

#[test]
fn heroes_block_ledger_stays_balanced() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = tiny_cfg("cnn");
    let mut env = FlEnv::build(&engine, cfg.clone()).unwrap();
    let mut rng = Rng::new(9);
    let mut server = HeroesServer::new(&env.info, &cfg, &mut rng).unwrap();
    for _ in 0..8 {
        server.run_round(&mut env).unwrap();
    }
    // every block must have been trained at least once after 8 rounds of
    // least-trained-first selection
    let (lo, hi) = server.ledger.count_range();
    assert!(lo > 0, "some block never trained (range {lo}..{hi})");
}

#[test]
fn heroes_rnn_round_runs() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = tiny_cfg("rnn");
    let mut env = FlEnv::build(&engine, cfg.clone()).unwrap();
    let mut rng = Rng::new(11);
    let mut server = HeroesServer::new(&env.info, &cfg, &mut rng).unwrap();
    let r = server.run_round(&mut env).unwrap();
    assert!(r.mean_loss.is_finite());
    let (loss, acc) = server.evaluate(&env).unwrap();
    assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
}

#[test]
fn heroes_resnet_round_runs() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = tiny_cfg("resnet");
    let mut env = FlEnv::build(&engine, cfg.clone()).unwrap();
    let mut rng = Rng::new(13);
    let mut server = HeroesServer::new(&env.info, &cfg, &mut rng).unwrap();
    let r = server.run_round(&mut env).unwrap();
    assert!(r.mean_loss.is_finite());
}

#[test]
fn same_seed_reproduces_run() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = tiny_cfg("cnn");
    let run = |seed: u64| {
        let mut c = cfg.clone();
        c.seed = seed;
        let mut env = FlEnv::build(&engine, c.clone()).unwrap();
        let mut rng = Rng::new(c.seed);
        let mut server = HeroesServer::new(&env.info, &c, &mut rng).unwrap();
        let mut sig = Vec::new();
        for _ in 0..3 {
            let r = server.run_round(&mut env).unwrap();
            sig.push((r.taus.clone(), r.widths.clone(), r.round_time));
        }
        (sig, server.evaluate(&env).unwrap())
    };
    let (a, ea) = run(123);
    let (b, eb) = run(123);
    assert_eq!(a, b, "same seed must reproduce the schedule exactly");
    assert_eq!(ea, eb);
    let (c, _) = run(124);
    assert_ne!(a, c, "different seed should differ");
}
