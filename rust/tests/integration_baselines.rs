//! Integration: the four baselines end-to-end (requires artifacts;
//! skips gracefully otherwise), plus cross-scheme comparisons that
//! encode the paper's qualitative claims at miniature scale.

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]


use heroes::baselines::{make_strategy, Strategy};
use heroes::config::{ExperimentConfig, Scale};
use heroes::coordinator::env::FlEnv;
use heroes::runtime::{EnginePool, Manifest};
use heroes::util::rng::Rng;

fn engine_or_skip() -> Option<EnginePool> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(EnginePool::single(Manifest::load(&dir).unwrap()).unwrap())
}

fn tiny_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("cnn", Scale::Smoke);
    cfg.n_clients = 8;
    cfg.k_per_round = 4;
    cfg.samples_per_client = 32;
    cfg.test_samples = 128;
    cfg.tau_default = 5;
    cfg
}

fn run_rounds(
    engine: &EnginePool,
    cfg: &ExperimentConfig,
    scheme: &str,
    rounds: usize,
) -> (Vec<heroes::coordinator::RoundReport>, (f64, f64), f64, f64) {
    let mut env = FlEnv::build(engine, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy(scheme, &env.info, cfg, &mut rng).unwrap();
    let mut reports = Vec::new();
    for _ in 0..rounds {
        reports.push(s.run_round(&mut env).unwrap());
    }
    let eval = s.evaluate(&env).unwrap();
    (reports, eval, env.clock.now(), env.traffic.total_gb())
}

#[test]
fn fedavg_trains_full_width_fixed_tau() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = tiny_cfg();
    let (reports, (loss, acc), _, _) = run_rounds(&engine, &cfg, "fedavg", 6);
    for r in &reports {
        assert!(r.widths.iter().all(|&p| p == 4), "fedavg must use full width");
        assert!(r.taus.iter().all(|&t| t == cfg.tau_default), "fedavg τ must be fixed");
    }
    assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
}

#[test]
fn adp_adapts_identical_tau() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = tiny_cfg();
    let (reports, _, _, _) = run_rounds(&engine, &cfg, "adp", 6);
    let mut distinct = std::collections::HashSet::new();
    for r in &reports {
        // identical τ within a round
        assert_eq!(r.taus.iter().min(), r.taus.iter().max(), "ADP τ must be identical per round");
        assert!(r.widths.iter().all(|&p| p == 4), "ADP keeps the full model");
        distinct.insert(r.taus[0]);
    }
    assert!(distinct.len() > 1, "ADP should adapt τ across rounds, saw {distinct:?}");
}

#[test]
fn heterofl_prunes_widths_by_capability() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = tiny_cfg();
    let (reports, (loss, _), _, _) = run_rounds(&engine, &cfg, "heterofl", 8);
    let mut widths = std::collections::HashSet::new();
    for r in &reports {
        for &p in &r.widths {
            widths.insert(p);
        }
        assert!(r.taus.iter().all(|&t| t == cfg.tau_default));
    }
    assert!(widths.len() > 1, "heterogeneous fleet must induce multiple widths: {widths:?}");
    assert!(loss.is_finite());
}

#[test]
fn flanc_runs_and_keeps_per_width_coefficients() {
    let Some(engine) = engine_or_skip() else { return };
    let cfg = tiny_cfg();
    let (reports, (loss, acc), _, _) = run_rounds(&engine, &cfg, "flanc", 8);
    assert!(reports.iter().all(|r| r.block_variance == 0.0), "flanc has no ledger");
    assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
}

#[test]
fn composed_uploads_are_smaller_than_dense() {
    // paper headline: NC transfers factors, MP transfers dense weights.
    let Some(engine) = engine_or_skip() else { return };
    let cfg = tiny_cfg();
    let (h_reports, _, _, _) = run_rounds(&engine, &cfg, "heroes", 4);
    let (f_reports, _, _, _) = run_rounds(&engine, &cfg, "fedavg", 4);
    let h_bytes: u64 = h_reports.iter().map(|r| r.up_bytes).sum();
    let f_bytes: u64 = f_reports.iter().map(|r| r.up_bytes).sum();
    assert!(
        heroes::util::cast::bytes_to_f64(h_bytes) < 0.6 * heroes::util::cast::bytes_to_f64(f_bytes),
        "heroes rounds should upload far less: {h_bytes} vs {f_bytes}"
    );
}

#[test]
fn heroes_waits_less_than_fedavg() {
    // paper Fig. 5: adaptive τ slashes the synchronization waiting time.
    let Some(engine) = engine_or_skip() else { return };
    let cfg = tiny_cfg();
    let (h_reports, _, _, _) = run_rounds(&engine, &cfg, "heroes", 8);
    let (f_reports, _, _, _) = run_rounds(&engine, &cfg, "fedavg", 8);
    // skip heroes' bootstrap round (identical τ there)
    let h_wait: f64 =
        h_reports[1..].iter().map(|r| r.avg_wait).sum::<f64>() / (h_reports.len() - 1) as f64;
    let f_wait: f64 = f_reports.iter().map(|r| r.avg_wait).sum::<f64>() / f_reports.len() as f64;
    assert!(
        h_wait < f_wait,
        "heroes should wait less than fedavg: {h_wait:.2}s vs {f_wait:.2}s"
    );
}

#[test]
fn all_schemes_same_seed_same_world() {
    // The environment must be identical across schemes (fair comparison):
    // same fleet classes, same first sampled batch labels.
    let Some(engine) = engine_or_skip() else { return };
    let cfg = tiny_cfg();
    let fleet_sig = |cfg: &ExperimentConfig| {
        let env = FlEnv::build(&engine, cfg.clone()).unwrap();
        env.fleet.devices.iter().map(|d| d.class.name().to_string()).collect::<Vec<_>>()
    };
    assert_eq!(fleet_sig(&cfg), fleet_sig(&cfg));
}
