//! The fault-injection subsystem's contracts (`simulation::faults`,
//! `coordinator::resilience` — see both module docs):
//!
//! 1. **Schedule purity** — a fault draw is a pure function of
//!    `(cfg, seed, round, client)`: re-evaluating the grid in any
//!    shuffled order reproduces every draw bit for bit, and `--faults
//!    off` never constructs an RNG, draws nothing, stamps nothing.
//! 2. **Retry budget** — no resolution ever pays more retries than the
//!    policy budget; recovered tasks only ever get *later* completions;
//!    abandoned tasks are lost at a positive fault instant.
//! 3. **Ledger** — the resilience ledger is an order-independent fold
//!    of per-task stamp decisions, with per-class conservation
//!    (observed = recovered + abandoned ≤ injected).
//! 4. **Policy paths** — every fault class demonstrably exercises its
//!    retry / replan / fail path with the matching ledger counts, using
//!    rate-1 schedules so nothing is left to sampling luck.
//! 5. **Quorum coupling** — the adaptive controller's chosen K is
//!    monotone non-decreasing in the observed fault rate.
//! 6. **Pipeline determinism** (artifacts-gated) — a faulted run's
//!    report series is bit-identical across `--workers`/`--pool`/
//!    `--overlap`, faults genuinely perturb the off-run bytes, and the
//!    `fail` policy aborts a real run with the typed error.
//!
//! PJRT-dependent tests require `make artifacts` and skip gracefully
//! otherwise (the same discipline as `tests/integration_parallel.rs`).

// Test/bench/example code: panicking on setup failure is idiomatic
// (CONTRIBUTING.md — the error-handling contract binds library code).
#![allow(clippy::unwrap_used, clippy::expect_used, clippy::indexing_slicing)]


use heroes::baselines::make_strategy;
use heroes::config::{ExperimentConfig, Scale};
use heroes::coordinator::env::FlEnv;
use heroes::coordinator::quorum_ctl::{QuorumController, QuorumCtlCfg, QuorumPolicy, QuorumSignals};
use heroes::coordinator::resilience::{
    rebill_for, resolve_fault, FaultAction, FaultPolicyCfg, FaultResolution, FaultStamp,
    FaultsCtl, ResilienceError, MAX_RETRY_BUDGET,
};
use heroes::coordinator::round::RoundDriver;
use heroes::coordinator::RoundReport;
use heroes::runtime::{EnginePool, Manifest};
use heroes::simulation::{FaultClass, FaultEvent, FaultsCfg, FAULT_CLASSES, MAX_SEVERITY};
use heroes::util::prop::check;
use heroes::util::rng::Rng;

// ---------------------------------------------------------------- purity

#[test]
fn prop_fault_schedules_are_pure_under_shuffled_evaluation() {
    // The determinism contract: the full (round, client) draw grid is
    // reproduced exactly when re-evaluated in a shuffled order — no
    // draw can depend on a shared cursor or evaluation history.
    check(
        71,
        40,
        |rng| {
            let rates: Vec<f64> = (0..3).map(|_| rng.uniform_in(0.05, 0.9)).collect();
            let seed = rng.next_u64();
            (rates, seed)
        },
        |(rates, seed)| {
            if rates.len() < 3 {
                return Ok(()); // shrinking artifact; generator emits 3
            }
            let cfg = FaultsCfg { exec: rates[0], corrupt: rates[1], partition: rates[2] };
            let grid: Vec<((usize, usize), Option<FaultEvent>)> = (0..10)
                .flat_map(|r| (0..10).map(move |c| ((r, c), cfg.draw(*seed, r, c))))
                .collect();
            let mut order: Vec<usize> = (0..grid.len()).collect();
            Rng::new(seed ^ 0xF00D).shuffle(&mut order);
            for i in order {
                let ((r, c), want) = grid[i];
                if cfg.draw(*seed, r, c) != want {
                    return Err(format!("draw ({r}, {c}) changed under re-evaluation"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn off_stamps_nothing_and_books_nothing() {
    // `--faults off` (the default) is inert at the stamp layer: no
    // draw, no stamp, no completion change, an empty ledger — the
    // byte-identity half of the acceptance gate that needs no PJRT.
    let mut ctl = FaultsCtl::new(FaultsCfg::default(), FaultPolicyCfg::default(), 9);
    ctl.note_dispatched(100);
    for round in 0..10 {
        for client in 0..10 {
            let r = ctl.stamp_one(round, client, 42.0, false).unwrap();
            assert_eq!(r, None, "off stamped ({round}, {client})");
        }
    }
    assert!(ctl.ledger().is_empty(), "off must keep the default ledger");
    assert_eq!(ctl.observed_fault_rate(), 0.0);
}

// ------------------------------------------------------------ resolution

#[test]
fn prop_retry_budget_is_never_exceeded() {
    // Over random events, budgets and backoffs: retries ≤ budget,
    // recovery only delays completions, abandonment happens at a
    // positive instant, and a dropout always masks the event.
    check(
        73,
        300,
        |rng| {
            let class = FAULT_CLASSES[rng.below(3)];
            let ev = FaultEvent {
                class,
                severity: 1 + rng.below(MAX_SEVERITY as usize) as u32,
                frac: rng.uniform_in(0.05, 0.95),
                stall: if class == FaultClass::Partition { rng.uniform_in(2.0, 30.0) } else { 0.0 },
                bit: rng.next_u64(),
            };
            let knobs = vec![
                rng.below(6) as f64,          // budget
                rng.uniform_in(0.0, 10.0),    // backoff
                rng.uniform_in(1.0, 500.0),   // completion
                rng.below(2) as f64,          // dropped?
            ];
            (vec![ev.severity as f64, ev.frac, ev.stall, ev.bit as f64], knobs, class_idx(class))
        },
        |(ev_raw, knobs, class_i)| {
            if ev_raw.len() < 4 || knobs.len() < 4 || *class_i >= FAULT_CLASSES.len() {
                return Ok(()); // shrinking artifact; generator emits full tuples
            }
            let class = FAULT_CLASSES[*class_i];
            let event = FaultEvent {
                class,
                severity: ev_raw[0] as u32,
                frac: ev_raw[1],
                stall: ev_raw[2],
                bit: ev_raw[3] as u64,
            };
            if event.severity == 0 || event.frac <= 0.0 || knobs[2] <= 0.0 {
                return Ok(()); // shrinking artifacts; the generator's
                               // ranges keep all three positive
            }
            let policy = FaultPolicyCfg {
                budget: knobs[0] as u32,
                backoff: knobs[1],
                ..FaultPolicyCfg::default()
            };
            let completion = knobs[2];
            let dropped = knobs[3] != 0.0;
            let r = resolve_fault(event, &policy, 3, 5, completion, dropped)
                .map_err(|e| e.to_string())?;
            match r {
                FaultResolution::Masked => {
                    if !dropped {
                        return Err("masked without a dropout".into());
                    }
                }
                FaultResolution::Recovered { stamp, new_completion } => {
                    if stamp.retries > policy.budget {
                        return Err(format!(
                            "retries {} exceed budget {}",
                            stamp.retries, policy.budget
                        ));
                    }
                    if !stamp.recovered || new_completion < completion {
                        return Err(format!(
                            "recovery must only delay: {completion} -> {new_completion}"
                        ));
                    }
                }
                FaultResolution::Abandoned { stamp } => {
                    if stamp.retries > policy.budget {
                        return Err(format!(
                            "retries {} exceed budget {}",
                            stamp.retries, policy.budget
                        ));
                    }
                    if stamp.recovered || stamp.fault_time <= 0.0 {
                        return Err(format!("bad abandonment stamp: {stamp:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

fn class_idx(class: FaultClass) -> usize {
    FAULT_CLASSES.iter().position(|c| *c == class).unwrap()
}

#[test]
fn large_retry_budgets_resolve_to_finite_backoff() {
    // The shift-overflow regression pin: `backoff · (2^n − 1)` written as
    // `(1u64 << n) - 1` panics (debug) or wraps (release) at n ≥ 64. The
    // exp2 formulation must stay finite and monotone across the 64
    // boundary. Severity just past each budget forces the abandonment
    // arm, whose fault_time pays all `budget` backoffs — the exact
    // expression the shift used to blow up.
    let mk_event = |severity: u32| FaultEvent {
        class: FaultClass::Exec,
        severity,
        frac: 0.5,
        stall: 0.0,
        bit: 1,
    };
    let mut last = 0.0;
    for budget in [63u32, 64, 65, 200] {
        let policy = FaultPolicyCfg { budget, backoff: 5.0, ..FaultPolicyCfg::default() };
        let r = resolve_fault(mk_event(budget + 1), &policy, 0, 0, 100.0, false).unwrap();
        match r {
            FaultResolution::Abandoned { stamp } => {
                assert!(
                    stamp.fault_time.is_finite() && stamp.fault_time > 0.0,
                    "budget {budget}: fault_time {} must be finite and positive",
                    stamp.fault_time
                );
                assert!(
                    stamp.fault_time > last,
                    "budget {budget}: more backoffs must cost more virtual time"
                );
                last = stamp.fault_time;
            }
            other => panic!("budget {budget}: expected abandonment, got {other:?}"),
        }
    }
    // and a contract-valid event (severity ≤ MAX_SEVERITY) under the
    // budget cap recovers with a finite delayed completion
    let policy =
        FaultPolicyCfg { budget: MAX_RETRY_BUDGET, backoff: 5.0, ..FaultPolicyCfg::default() };
    match resolve_fault(mk_event(MAX_SEVERITY), &policy, 0, 0, 100.0, false).unwrap() {
        FaultResolution::Recovered { stamp, new_completion } => {
            assert!(stamp.recovered);
            assert!(
                new_completion.is_finite() && new_completion > 100.0,
                "capped budget must recover with a finite delay, got {new_completion}"
            );
        }
        other => panic!("expected recovery under the budget cap, got {other:?}"),
    }
}

// ---------------------------------------------------------------- ledger

#[test]
fn prop_ledger_is_an_order_independent_fold() {
    // Stamping the same task set in any permutation books the same
    // ledger, and per class observed = recovered + abandoned ≤ injected.
    check(
        79,
        40,
        |rng| {
            let n = 8 + rng.below(40);
            let seed = rng.next_u64();
            let mut order: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut order);
            (order, seed)
        },
        |(order, seed)| {
            let cfg = FaultsCfg { exec: 0.35, corrupt: 0.3, partition: 0.35 };
            let run = |clients: &[usize]| {
                let mut ctl = FaultsCtl::new(cfg, FaultPolicyCfg::default(), *seed);
                ctl.note_dispatched(clients.len());
                for &client in clients {
                    ctl.stamp_one(1, client, 30.0 + client as f64, client % 7 == 0).unwrap();
                }
                *ctl.ledger()
            };
            let sorted: Vec<usize> = {
                let mut v = order.clone();
                v.sort_unstable();
                v
            };
            let a = run(order);
            let b = run(&sorted);
            if a != b {
                return Err(format!("ledger depends on stamp order: {a:?} vs {b:?}"));
            }
            for class in FAULT_CLASSES {
                let c = a.counts(class);
                if c.observed != c.recovered + c.abandoned || c.observed > c.injected {
                    return Err(format!("{class:?} counts violate conservation: {c:?}"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------- policy paths

#[test]
fn every_class_exercises_its_policy_path_with_ledger_counts() {
    // Rate-1 single-class schedules leave nothing to sampling luck:
    // each class × action pair lands in exactly the ledger bucket its
    // policy promises.
    let one = |class: &str| FaultsCfg::parse(&format!("{class}=1")).unwrap();

    // exec + retry with the budget at the severity cap: every fault
    // recovers, every retry is booked
    let mut ctl = FaultsCtl::new(
        one("exec"),
        FaultPolicyCfg { budget: MAX_SEVERITY, ..FaultPolicyCfg::default() },
        21,
    );
    ctl.note_dispatched(16);
    for client in 0..16 {
        let (stamp, new_completion) = ctl.stamp_one(0, client, 50.0, false).unwrap().unwrap();
        assert!(stamp.recovered, "budget ≥ MAX_SEVERITY must always recover");
        assert!(new_completion > 50.0, "recovery must pay the retry delay");
        assert_eq!(stamp.event.class, FaultClass::Exec);
    }
    let l = ctl.ledger();
    assert_eq!((l.exec.injected, l.exec.observed, l.exec.recovered), (16, 16, 16));
    assert_eq!(l.exec.abandoned, 0);
    assert!(l.exec.retried >= 16, "each fault pays ≥ 1 retry, got {}", l.exec.retried);
    assert_eq!(ctl.observed_fault_rate(), 1.0);

    // exec + retry with budget 0: severity ≥ 1 always exhausts it —
    // every fault abandons, after exactly 0 paid retries
    let mut ctl = FaultsCtl::new(
        one("exec"),
        FaultPolicyCfg { budget: 0, ..FaultPolicyCfg::default() },
        21,
    );
    ctl.note_dispatched(16);
    for client in 0..16 {
        let (stamp, _) = ctl.stamp_one(0, client, 50.0, false).unwrap().unwrap();
        assert!(!stamp.recovered && stamp.fault_time > 0.0);
    }
    let l = ctl.ledger();
    assert_eq!((l.exec.abandoned, l.exec.recovered, l.exec.retried), (16, 0, 0));

    // corrupt + replan: abandoned at the manifest instant, no retries
    let mut ctl = FaultsCtl::new(
        one("corrupt"),
        FaultPolicyCfg::parse("corrupt=replan").unwrap(),
        22,
    );
    ctl.note_dispatched(8);
    for client in 0..8 {
        let (stamp, _) = ctl.stamp_one(0, client, 50.0, false).unwrap().unwrap();
        assert_eq!(stamp.action, FaultAction::Replan);
        assert!(!stamp.recovered && stamp.retries == 0);
    }
    assert_eq!(ctl.ledger().corrupt.abandoned, 8);

    // partition + retry: always recovered by waiting the stall out
    let mut ctl = FaultsCtl::new(one("partition"), FaultPolicyCfg::default(), 23);
    ctl.note_dispatched(8);
    for client in 0..8 {
        let (stamp, new_completion) = ctl.stamp_one(0, client, 50.0, false).unwrap().unwrap();
        assert!(stamp.recovered);
        assert!((new_completion - 50.0 - stamp.event.stall).abs() < 1e-12);
    }
    assert_eq!(ctl.ledger().partition.recovered, 8);

    // any class + fail: the first stamp aborts typed
    let mut ctl = FaultsCtl::new(one("exec"), FaultPolicyCfg::parse("fail").unwrap(), 24);
    ctl.note_dispatched(1);
    let err = ctl.stamp_one(4, 9, 50.0, false).unwrap_err();
    assert_eq!(
        err.downcast_ref::<ResilienceError>(),
        Some(&ResilienceError::FaultAbort { round: 4, client: 9, class: FaultClass::Exec })
    );

    // a scenario dropout masks even a rate-1 fault (injected, never
    // observed)
    let mut ctl = FaultsCtl::new(one("exec"), FaultPolicyCfg::parse("fail").unwrap(), 24);
    ctl.note_dispatched(1);
    assert_eq!(ctl.stamp_one(4, 9, 50.0, true).unwrap(), None);
    let l = ctl.ledger();
    assert_eq!((l.exec.injected, l.exec.observed), (1, 0));
}

#[test]
fn recovered_corrupt_retries_rebill_upload_traffic() {
    // PR 8 follow-up: a recovered `corrupt` fault re-sent its upload
    // frame on every retry, so the retransmitted bytes are billed on
    // top of the planned frame — and only in that case.
    let stamp = |class: FaultClass, retries: u32, recovered: bool| FaultStamp {
        event: FaultEvent { class, severity: retries.max(1), frac: 0.5, stall: 0.0, bit: 3 },
        action: FaultAction::Retry,
        retries,
        recovered,
        fault_time: if recovered { 0.0 } else { 7.5 },
    };

    // the one re-billing case: recovered corrupt, retries × frame bytes
    assert_eq!(rebill_for(&stamp(FaultClass::Corrupt, 2, true), 1000), 2000);
    assert_eq!(rebill_for(&stamp(FaultClass::Corrupt, 1, true), 64), 64);
    // zero-retry recovery re-sent nothing
    assert_eq!(rebill_for(&stamp(FaultClass::Corrupt, 0, true), 1000), 0);
    // exec retries re-run compute, partitions stall one frame in flight
    assert_eq!(rebill_for(&stamp(FaultClass::Exec, 3, true), 1000), 0);
    assert_eq!(rebill_for(&stamp(FaultClass::Partition, 0, true), 1000), 0);
    // an unrecovered corrupt never completed its upload
    assert_eq!(rebill_for(&stamp(FaultClass::Corrupt, 2, false), 1000), 0);
    // saturation, not overflow, on absurd inputs
    assert_eq!(rebill_for(&stamp(FaultClass::Corrupt, u32::MAX, true), u64::MAX), u64::MAX);

    // the ledger books re-billed bytes as an order-independent sum and
    // exports them in the run output JSON
    let mut ctl = FaultsCtl::new(
        FaultsCfg::parse("corrupt=1").unwrap(),
        FaultPolicyCfg { budget: MAX_SEVERITY, ..FaultPolicyCfg::default() },
        31,
    );
    ctl.note_dispatched(8);
    let mut expected = 0u64;
    for client in 0..8 {
        let (stamp, _) = ctl.stamp_one(0, client, 50.0, false).unwrap().unwrap();
        assert!(stamp.recovered, "budget ≥ MAX_SEVERITY must always recover");
        let rebill = rebill_for(&stamp, 500);
        assert_eq!(rebill, 500 * u64::from(stamp.retries));
        if rebill > 0 {
            ctl.note_rebilled(rebill);
            expected += rebill;
        }
    }
    assert!(expected > 0, "rate-1 corrupt with severities ≥ 1 must re-bill something");
    assert_eq!(ctl.ledger().rebilled_bytes, expected);
    let j = ctl.ledger().to_json();
    assert_eq!(j.get("rebilled_bytes").unwrap().as_u64(), Some(expected));
}

// --------------------------------------------------------- quorum signal

#[test]
fn prop_adaptive_k_is_monotone_in_the_fault_rate() {
    // Observed faults are churn: at fixed α, a rising fault rate can
    // only grow the chosen K, never shrink it.
    check(
        83,
        120,
        |rng| {
            let n = 2 + rng.below(18);
            let completions: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.1, 30.0)).collect();
            (completions, rng.uniform_in(0.0, 2.0))
        },
        |(completions, alpha)| {
            if completions.is_empty() {
                return Ok(()); // shrinking artifact; rejected upstream
            }
            let mut cfg = QuorumCtlCfg::new(0.8, 1, 0.5, *alpha);
            cfg.alpha_gain = 0.0; // isolate the K rule
            let mut prev = 0usize;
            for step in 0..=10 {
                let sig = QuorumSignals {
                    fault_rate: step as f64 * 0.05,
                    ..QuorumSignals::default()
                };
                let mut ctl = QuorumController::new(cfg);
                let d = ctl.decide(completions, &sig);
                if d.k < prev {
                    return Err(format!(
                        "K shrank from {prev} to {} as the fault rate rose to {}",
                        d.k,
                        step as f64 * 0.05
                    ));
                }
                prev = d.k;
            }
            Ok(())
        },
    );
}

// --------------------------------------------- pipeline (artifacts-gated)

fn pool_or_skip(engines: usize) -> Option<EnginePool> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(EnginePool::new(Manifest::load(&dir).unwrap(), engines).unwrap())
}

fn faulted_cfg(workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("cnn", Scale::Smoke);
    cfg.n_clients = 8;
    cfg.k_per_round = 4;
    cfg.samples_per_client = 32;
    cfg.test_samples = 128;
    cfg.tau_default = 3;
    cfg.tau_max = 12;
    cfg.workers = workers;
    cfg.faults = FaultsCfg::parse("exec=0.5,corrupt=0.4,partition=0.5").unwrap();
    // the budget at the severity cap: every retry-class fault recovers,
    // so no round can lose its whole cohort to abandonment
    cfg.fault_policy =
        FaultPolicyCfg { budget: MAX_SEVERITY, ..FaultPolicyCfg::default() };
    cfg
}

/// Per-round (full-barrier) reports plus the run's resilience ledger.
fn run_faulted(
    pool: &EnginePool,
    cfg: &ExperimentConfig,
    rounds: usize,
) -> (Vec<RoundReport>, heroes::coordinator::resilience::ResilienceLedger, (f64, f64)) {
    let mut env = FlEnv::build(pool, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy("heroes", &env.info, cfg, &mut rng).unwrap();
    let reports = (0..rounds).map(|_| s.run_round(&mut env).unwrap()).collect();
    let eval = s.evaluate(&env).unwrap();
    (reports, *env.resilience(), eval)
}

fn run_faulted_overlapped(
    pool: &EnginePool,
    cfg: &ExperimentConfig,
    rounds: usize,
) -> (Vec<RoundReport>, heroes::coordinator::resilience::ResilienceLedger, (f64, f64)) {
    let mut env = FlEnv::build(pool, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy("heroes", &env.info, cfg, &mut rng).unwrap();
    let driver = RoundDriver::new(cfg.workers);
    let reports = driver.run_overlapped(pool, &mut env, s.as_mut(), rounds).unwrap();
    let eval = s.evaluate(&env).unwrap();
    (reports, *env.resilience(), eval)
}

#[test]
fn faulted_runs_are_identical_across_workers_pool_and_overlap() {
    // The acceptance pin: retry outcomes are plan facts, so a faulted
    // run's report series, ledger and final model are bit-identical for
    // workers=1, workers=4 (shared engine and per-worker pool) and
    // overlapped dispatch.
    let Some(shared) = pool_or_skip(1) else { return };
    let Some(pooled) = pool_or_skip(4) else { return };
    let rounds = 3;
    let (serial, ledger1, eval1) = run_faulted(&shared, &faulted_cfg(1), rounds);
    let (threads, ledger4, eval4) = run_faulted(&shared, &faulted_cfg(4), rounds);
    let (pool4, ledger4p, eval4p) = run_faulted(&pooled, &faulted_cfg(4), rounds);
    let (overlap, ledger_o, eval_o) = run_faulted_overlapped(&pooled, &faulted_cfg(4), rounds);
    assert_eq!(serial, threads, "workers must not change faulted rounds");
    assert_eq!(serial, pool4, "the engine pool must not change faulted rounds");
    assert_eq!(serial, overlap, "overlap must not change faulted rounds");
    assert_eq!(ledger1, ledger4, "the ledger is a plan fact");
    assert_eq!(ledger1, ledger4p);
    assert_eq!(ledger1, ledger_o);
    assert_eq!(eval1, eval4, "workers changed the faulted final model");
    assert_eq!(eval1, eval4p);
    assert_eq!(eval1, eval_o);

    // the schedule genuinely fired (combined rate ≈ 0.86 over 12 tasks)
    // and every observed fault recovered under the capped budget
    assert!(ledger1.dispatched >= 12 && !ledger1.is_empty(), "no faults drawn: {ledger1:?}");
    for class in FAULT_CLASSES {
        let c = ledger1.counts(class);
        assert_eq!(c.abandoned, 0, "{class:?}: budget = MAX_SEVERITY cannot abandon");
        assert_eq!(c.recovered, c.observed);
    }

    // and the injection is real: the same seed with faults off produces
    // different bytes (retry/stall delays move completion times)
    let mut off = faulted_cfg(1);
    off.faults = FaultsCfg::default();
    let (clean, ledger_off, _) = run_faulted(&shared, &off, rounds);
    assert!(ledger_off.is_empty(), "off run must book nothing");
    assert_ne!(serial, clean, "a faulted run must not reproduce the clean bytes");
}

#[test]
fn faulted_quorum_runs_are_deterministic_and_report_the_fault_rate() {
    // The semi-async path under fault pressure: deterministic for any
    // worker count, and the adaptive controller sees a non-zero
    // observed fault rate (the ledger feeds QuorumSignals::fault_rate).
    let Some(shared) = pool_or_skip(1) else { return };
    let Some(pooled) = pool_or_skip(4) else { return };
    let rounds = 4;
    let run = |pool: &EnginePool, workers: usize| {
        let cfg = faulted_cfg(workers);
        let mut env = FlEnv::build(pool, cfg.clone()).unwrap();
        let mut rng = Rng::new(cfg.seed ^ 0x5EED);
        let mut s = make_strategy("heroes", &env.info, &cfg, &mut rng).unwrap();
        let driver = RoundDriver::new(cfg.workers);
        let mut policy = QuorumPolicy::fixed(2, 1.0);
        let reports =
            driver.run_quorum(pool, &mut env, s.as_mut(), rounds, &mut policy, None).unwrap();
        (reports, *env.resilience(), s.evaluate(&env).unwrap())
    };
    let (q1, l1, e1) = run(&shared, 1);
    let (q4, l4, e4) = run(&pooled, 4);
    assert_eq!(q1, q4, "faulted quorum rounds must not depend on worker count");
    assert_eq!(l1, l4, "the quorum-path ledger is a plan fact");
    assert_eq!(e1, e4);
    assert!(l1.observed_rate() > 0.0, "fault pressure must be visible to the controller");
}

#[test]
fn fail_policy_aborts_a_real_run_with_the_typed_error() {
    // `--fault-policy fail` + a rate-1 exec schedule: round 0's first
    // stamp aborts before any engine work, and the error downcasts.
    let Some(pool) = pool_or_skip(1) else { return };
    let mut cfg = faulted_cfg(1);
    cfg.faults = FaultsCfg::parse("exec=1").unwrap();
    cfg.fault_policy = FaultPolicyCfg::parse("fail").unwrap();
    let mut env = FlEnv::build(&pool, cfg.clone()).unwrap();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut s = make_strategy("heroes", &env.info, &cfg, &mut rng).unwrap();
    let err = s.run_round(&mut env).unwrap_err();
    match err.downcast_ref::<ResilienceError>() {
        Some(&ResilienceError::FaultAbort { round: 0, class: FaultClass::Exec, .. }) => {}
        other => panic!("expected a typed FaultAbort, got {other:?} ({err})"),
    }
}
